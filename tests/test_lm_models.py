"""LM model tests: smoke per arch, decode consistency, layer properties."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES
from repro.configs.registry import ARCH_IDS, cell_supported, get_config
from repro.data.pipeline import make_batch
from repro.models import transformer as T
from repro.models.layers import blocked_attention, moe_block, rms_norm, rope


def _batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    b = {"tokens": rng.integers(0, cfg.vocab, (B, S)).astype(np.int32)}
    if cfg.n_prefix:
        b["patches"] = rng.standard_normal((B, cfg.n_prefix, cfg.d_model)).astype(jnp.bfloat16)
    if cfg.n_encoder_layers:
        b["frames"] = rng.standard_normal((B, cfg.n_audio_frames, cfg.d_model)).astype(jnp.bfloat16)
    return b


# -- per-arch smoke (deliverable f): reduced config, one step, shapes + finite


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_train_step(arch):
    cfg = get_config(arch, reduced=True)
    params = T.init_params(cfg, 0)
    batch = _batch(cfg)
    loss, metrics = jax.jit(lambda p, b: T.loss_fn(p, b, cfg))(params, batch)
    assert np.isfinite(float(loss))
    assert float(loss) > 0
    logits, _ = jax.jit(lambda p, b: T.forward(p, b, cfg))(params, batch)
    assert logits.shape == (2, 32, cfg.padded_vocab())
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ["qwen3-4b", "mamba2-780m", "whisper-tiny",
                                  "llama4-scout-17b-a16e"])
def test_decode_matches_forward(arch):
    cfg = get_config(arch, reduced=True)
    if cfg.is_moe:  # avoid capacity-drop mismatch noise (GShard semantics)
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = T.init_params(cfg, 0)
    B, S = 2, 17
    batch = _batch(cfg, B, S, seed=1)
    full, _ = jax.jit(lambda p, b: T.forward(p, b, cfg))(params, batch)
    ref = full[:, -1].astype(np.float32)
    pb = dict(batch)
    pb["tokens"] = batch["tokens"][:, :-1]
    pf = jax.jit(lambda p, b: T.prefill(p, b, cfg, max_len=S + cfg.n_prefix))(params, pb)
    db = {"tokens": batch["tokens"][:, -1:], "cache_len": pf["cache_len"]}
    if "memory" in pf:
        db["memory"] = pf["memory"]
    dec, _ = jax.jit(lambda p, c, b: T.decode_step(p, c, b, cfg))(params, pf["cache"], db)
    got = dec[:, 0].astype(np.float32)
    rel = float(jnp.max(jnp.abs(ref - got)) / (jnp.max(jnp.abs(ref)) + 1e-9))
    assert rel < 0.05, rel


def test_param_specs_match_init_shapes():
    for arch in ("qwen3-moe-30b-a3b", "jamba-v0.1-52b"):
        cfg = get_config(arch, reduced=True)
        specs = T.param_specs(cfg)
        params = T.init_params(cfg, 0)
        s_flat = jax.tree_util.tree_flatten_with_path(specs)[0]
        p_flat = jax.tree_util.tree_flatten_with_path(params)[0]
        assert len(s_flat) == len(p_flat)
        for (ps, s), (pp, p) in zip(s_flat, p_flat):
            assert ps == pp
            assert tuple(s.shape) == tuple(np.shape(p)), (ps, s.shape, np.shape(p))


def test_input_specs_cover_all_cells():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, _ = cell_supported(cfg, shape)
            if not ok:
                continue
            specs = T.input_specs(cfg, shape)
            assert "params" in specs and "batch" in specs
            if shape.kind == "decode":
                assert "cache" in specs
                ktree = jax.tree_util.tree_leaves(specs["cache"])
                assert all(hasattr(k, "shape") for k in ktree)


def test_param_count_sane():
    approx = {
        "qwen3-4b": (3e9, 6e9),
        "command-r-35b": (30e9, 40e9),
        "mamba2-780m": (0.6e9, 1.1e9),
        "internlm2-1.8b": (1.5e9, 2.4e9),
        "stablelm-1.6b": (1.3e9, 2.2e9),
        "llama4-scout-17b-a16e": (90e9, 120e9),   # total (not active)
        "jamba-v0.1-52b": (45e9, 60e9),
    }
    for arch, (lo, hi) in approx.items():
        n = get_config(arch).param_count()
        assert lo < n < hi, f"{arch}: {n / 1e9:.1f}B"


# -- layer-level properties


def test_rope_preserves_norm_and_relative_phase():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((1, 8, 2, 16)), jnp.float32)
    pos = jnp.arange(8)
    y = rope(x, pos, 1e4)
    np.testing.assert_allclose(
        jnp.linalg.norm(y, axis=-1), jnp.linalg.norm(x, axis=-1), rtol=1e-5
    )
    # dot(q_i, k_j) depends only on i-j: shift both positions by 3
    q, k = x[:, :4], x[:, :4]
    y1 = rope(q, jnp.arange(4), 1e4)
    y2 = rope(k, jnp.arange(4) + 3, 1e4)
    z1 = rope(q, jnp.arange(4) + 5, 1e4)
    z2 = rope(k, jnp.arange(4) + 8, 1e4)
    d1 = jnp.einsum("bshd,bthd->bsht", y1, y2)
    d2 = jnp.einsum("bshd,bthd->bsht", z1, z2)
    np.testing.assert_allclose(d1, d2, rtol=1e-4, atol=1e-5)


def test_rms_norm_unit_scale():
    x = jnp.asarray(np.random.default_rng(0).standard_normal((4, 64)) * 10,
                    jnp.float32)
    y = rms_norm(x, jnp.zeros(64))
    rms = jnp.sqrt(jnp.mean(y * y, axis=-1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-2)


def test_blocked_attention_matches_small_path():
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.standard_normal((2, 96, 4, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 96, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 96, 2, 16)), jnp.float32)
    pos = jnp.arange(96)
    small = blocked_attention(q, k, v, q_positions=pos, k_positions=pos,
                              block_q=96)
    blocked = blocked_attention(q, k, v, q_positions=pos, k_positions=pos,
                                block_q=32)
    np.testing.assert_allclose(small, blocked, rtol=1e-4, atol=1e-5)


def test_moe_conserves_tokens_and_drops_bounded():
    from repro.configs.base import ArchConfig

    cfg = ArchConfig(name="t", family="moe", n_layers=1, d_model=32, n_heads=2,
                     n_kv_heads=2, d_ff=64, vocab=64, n_experts=4,
                     experts_per_token=2, moe_d_ff=64, capacity_factor=8.0)
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.standard_normal((4, 16, 32)), jnp.float32)
    p = {
        "router": jnp.asarray(rng.standard_normal((32, 4)) * 0.1, jnp.float32),
        "gate": jnp.asarray(rng.standard_normal((4, 32, 64)) * 0.1, jnp.float32),
        "up": jnp.asarray(rng.standard_normal((4, 32, 64)) * 0.1, jnp.float32),
        "down": jnp.asarray(rng.standard_normal((4, 64, 32)) * 0.1, jnp.float32),
    }
    out, aux = moe_block(x, p, cfg)
    assert out.shape == x.shape
    assert np.isfinite(float(aux)) and float(aux) >= 1.0 - 1e-3  # ≥1 by Switch aux defn
    # with cf=8 nothing is dropped: every token got k expert outputs
    assert float(jnp.mean(jnp.abs(out))) > 1e-4
