"""CNN zoo: shape propagation vs real forward, spec/param consistency."""

import jax
import numpy as np
import pytest

from repro.models.cnn import CNN_BUILDERS


@pytest.mark.parametrize("family", list(CNN_BUILDERS))
def test_forward_matches_shape_pass(family):
    m = CNN_BUILDERS[family](width_mult=0.25, input_hw=16)
    params = m.init(0)
    x = np.random.default_rng(0).standard_normal((2, 16, 16, 3)).astype(np.float32)
    logits = jax.jit(m.apply)(params, x)
    assert logits.shape == (2, m.num_classes)
    assert bool(np.all(np.isfinite(np.asarray(logits))))


@pytest.mark.parametrize("family", list(CNN_BUILDERS))
def test_spec_layer_geometry_consistent(family):
    m = CNN_BUILDERS[family](width_mult=0.25, input_hw=16)
    spec = m.conv_specs()
    for l in spec.layers:
        assert l.n >= 1 and l.m >= 1 and l.op >= 1
        if l.groups > 1:  # depthwise: groups == in channels
            assert l.groups == l.m


def test_num_params_matches_actual():
    m = CNN_BUILDERS["resnet18"](width_mult=0.25)
    params = m.init(0)
    actual_conv = sum(
        a.size for path, a in jax.tree_util.tree_flatten_with_path(params)[0]
        if path[-1].key == "w"
    )
    # num_params counts conv + dense weight tensors (spec-derived)
    assert abs(m.num_params() - actual_conv) / actual_conv < 1e-6


def test_width_mult_scales_params():
    small = CNN_BUILDERS["squeezenet"](width_mult=0.25).num_params()
    big = CNN_BUILDERS["squeezenet"](width_mult=0.5).num_params()
    assert 2.5 < big / small < 5.0  # ~quadratic in width
