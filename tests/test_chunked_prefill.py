"""Chunked prefill (ISSUE 10): greedy streams bit-identical to solo /
unchunked runs — including across a preemption mid-prompt — plus the new
decode-path observability metrics."""

import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.models import transformer as T
from repro.serve import ContinuousConfig, ContinuousEngine, Request


@pytest.fixture(scope="module")
def model():
    cfg = get_config("internlm2-1.8b", reduced=True)
    return cfg, T.init_params(cfg, 0)


def _prompts(lens, seed=0, vocab=128):
    rng = np.random.default_rng(seed)
    return [rng.integers(2, vocab, (n,)).astype(np.int32) for n in lens]


def _streams(engine):
    return sorted((tuple(r.prompt.tolist()), tuple(r.tokens))
                  for r in engine.finished)


def _run(cfg, params, prompts, scfg, max_new=8):
    eng = ContinuousEngine(cfg, params, scfg)
    eng.run([Request(p, max_new_tokens=max_new) for p in prompts])
    return eng


@pytest.mark.parametrize("chunk", [8, 16])
def test_chunked_streams_match_unchunked(model, chunk):
    cfg, params = model
    prompts = _prompts((5, 37, 21, 50, 16))   # incl. exact chunk multiples
    base = _run(cfg, params, prompts,
                ContinuousConfig(max_len=128, n_slots=3, seed=0))
    chk = _run(cfg, params, prompts,
               ContinuousConfig(max_len=128, n_slots=3, seed=0,
                                prefill_chunk=chunk))
    assert _streams(chk) == _streams(base)
    m = chk.metrics()
    assert m["prefill_chunks"] > 0
    assert m["lost"] == 0 and m["finished"] == len(prompts)


def test_chunk_geq_prompt_is_solo_path(model):
    # prompts never exceeding the chunk take the ordinary prefill path
    cfg, params = model
    prompts = _prompts((5, 9))
    eng = _run(cfg, params, prompts,
               ContinuousConfig(max_len=64, n_slots=2, seed=0,
                                prefill_chunk=16))
    assert eng.counters["prefill_chunks"] == 0
    assert len(eng.finished) == 2


def test_decode_never_stalls_and_bytes_accounting(model):
    cfg, params = model
    eng = _run(cfg, params, _prompts((40, 7, 33)),
               ContinuousConfig(max_len=128, n_slots=2, seed=0,
                                prefill_chunk=8))
    m = eng.metrics()
    assert m["max_decode_stall_steps"] == 0
    # gather materialises the pow2 table width for every slot; the kernel
    # touches only live blocks — strictly less on any ragged trace
    assert 0 < m["kv_touched_bytes"] < m["kv_gathered_bytes"]


def test_preemption_mid_prompt_resumes_identically(model):
    """A young long prompt is preempted while still mid-chunked-prefill
    (an older slot crosses a block boundary and drains the pool), then
    resumes and finishes with exactly the solo greedy stream."""
    cfg, params = model
    rng = np.random.default_rng(4)
    p_old = rng.integers(2, 128, (14,)).astype(np.int32)
    p_new = rng.integers(2, 128, (40,)).astype(np.int32)
    scfg = ContinuousConfig(max_len=64, n_slots=2, seed=0, block_size=16,
                            pool_tokens=64, prefill_chunk=8)
    eng = ContinuousEngine(cfg, params, scfg)
    old = Request(p_old, max_new_tokens=10)
    new = Request(p_new, max_new_tokens=6)
    eng.run([old, new])

    assert eng.counters["preemptions"] >= 1
    assert eng.counters["resumes"] >= 1
    assert new.preemptions >= 1
    assert eng.metrics()["lost"] == 0
    assert {r.rid for r in eng.finished} == {old.rid, new.rid}

    # solo references: ample pool, no contention, chunked or not
    for req, max_new in ((old, 10), (new, 6)):
        solo = _run(cfg, params, [req.prompt],
                    ContinuousConfig(max_len=64, n_slots=2, seed=0),
                    max_new=max_new)
        assert tuple(solo.finished[0].tokens) == tuple(req.tokens)


def test_engine_kernel_path_matches_gather(model, monkeypatch):
    """End-to-end greedy decode through the interpret-mode Pallas kernel
    equals the gather fallback (token streams, not logits — argmax
    absorbs bf16 drift)."""
    cfg, params = model
    prompts = _prompts((5, 11), seed=2)

    def run(mode):
        monkeypatch.setenv("REPRO_PAGED_DECODE", mode)
        return _streams(_run(cfg, params, prompts,
                             ContinuousConfig(max_len=32, n_slots=2, seed=0),
                             max_new=4))

    assert run("interpret") == run("gather")
