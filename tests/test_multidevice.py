"""Multi-device behaviour tests, run in subprocesses with forced host
devices (the flag must never leak into this process — see dryrun.py note)."""

import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str, n_devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(script)],
        capture_output=True, text=True, env=env, timeout=600, cwd=ROOT,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_sharded_train_step_matches_single_device():
    _run("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.configs.registry import get_config
        from repro.configs.base import ShapeSpec
        from repro.models import transformer as T
        from repro.distributed import sharding as sh
        from repro.launch.mesh import make_mesh
        from repro.models import layers as L

        cfg = get_config("internlm2-1.8b", reduced=True)
        shape = ShapeSpec("t", 32, 8, "train")
        params = T.init_params(cfg, 0)
        rng = np.random.default_rng(0)
        batch = {"tokens": rng.integers(0, cfg.vocab, (8, 32)).astype(np.int32)}

        # single-device reference
        l_ref, _ = jax.jit(lambda p, b: T.loss_fn(p, b, cfg))(params, batch)

        mesh = make_mesh((2, 4), ("data", "model"))
        L.set_hint_mesh(mesh)
        pspec = sh.param_pspecs(cfg, mesh)
        bspec = sh.batch_pspecs(cfg, shape, mesh)
        fn = jax.jit(lambda p, b: T.loss_fn(p, b, cfg)[0],
                     in_shardings=(sh.to_named(mesh, pspec), sh.to_named(mesh, bspec)))
        with mesh:
            l_sh = fn(params, batch)
        err = abs(float(l_ref) - float(l_sh)) / abs(float(l_ref))
        assert err < 2e-2, (float(l_ref), float(l_sh))
        print("OK", float(l_ref), float(l_sh))
    """)


def test_moe_arch_sharded_matches():
    _run("""
        import numpy as np, jax, dataclasses
        from repro.configs.registry import get_config
        from repro.configs.base import ShapeSpec
        from repro.models import transformer as T
        from repro.distributed import sharding as sh
        from repro.launch.mesh import make_mesh
        from repro.models import layers as L

        cfg = dataclasses.replace(get_config("qwen3-moe-30b-a3b", reduced=True),
                                  capacity_factor=8.0)
        shape = ShapeSpec("t", 16, 4, "train")
        params = T.init_params(cfg, 0)
        rng = np.random.default_rng(0)
        batch = {"tokens": rng.integers(0, cfg.vocab, (4, 16)).astype(np.int32)}
        l_ref, _ = jax.jit(lambda p, b: T.loss_fn(p, b, cfg))(params, batch)

        mesh = make_mesh((2, 4), ("data", "model"))
        L.set_hint_mesh(mesh)
        fn = jax.jit(lambda p, b: T.loss_fn(p, b, cfg)[0],
                     in_shardings=(sh.to_named(mesh, sh.param_pspecs(cfg, mesh)),
                                   sh.to_named(mesh, sh.batch_pspecs(cfg, shape, mesh))))
        with mesh:
            l_sh = fn(params, batch)
        err = abs(float(l_ref) - float(l_sh)) / abs(float(l_ref))
        assert err < 2e-2, (float(l_ref), float(l_sh))
        print("OK")
    """)


def test_pipeline_parallel_matches_sequential():
    _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.distributed.pipeline_parallel import pipeline_apply, bubble_fraction
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((4,), ("pipe",))
        n_stages, n_micro, mb, d = 4, 8, 2, 16
        rng = np.random.default_rng(0)
        ws = jnp.asarray(rng.standard_normal((n_stages, d, d)) * 0.3, jnp.float32)
        x = jnp.asarray(rng.standard_normal((n_micro, mb, d)), jnp.float32)

        def stage_fn(w, h):
            return jnp.tanh(h @ w)

        out = pipeline_apply(stage_fn, ws, x, mesh)

        ref = x
        for s in range(n_stages):
            ref = jax.vmap(lambda h: stage_fn(ws[s], h))(ref)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
        assert abs(bubble_fraction(4, 8) - 3/11) < 1e-9
        print("OK")
    """)


def test_campaign_cell_collectives_on_2dev_mesh(tmp_path):
    """Mesh-dim feature validation (ROADMAP "Next" item): a data-parallel
    2-device grid lowered through launch/lowering must parse nonzero
    collective bytes — with collective-class ledger records to match —
    while the same cell on 1x1 parses exactly zero.  Otherwise every mesh
    feature the campaign fits on is vacuously zero."""
    _run("""
        from repro.campaign.plan import plan_grid
        from repro.campaign.runner import measure_cell

        results = {}
        for mesh in ("1x1", "2x1"):
            plan = plan_grid(archs=("qwen3-4b",),
                             shapes=("smoke_train_16x2",), meshes=(mesh,))
            assert len(plan.cells) == 1, (mesh, plan.skipped)
            # compile-only: collective bytes come from the HLO parse
            results[mesh] = measure_cell(plan.cells[0], run=False)

        one, two = results["1x1"], results["2x1"]
        assert one["collective_bytes"] == 0.0, one["collective_bytes"]
        assert "collective" not in one["cost_classes"]
        assert two["collective_bytes"] > 0.0
        assert two["n_devices"] == 2

        # ledger attribution agrees with the scalar: the collective class
        # carries ALL of it, and the breakdown re-sums exactly
        classes = two["cost_classes"]
        coll = sum(s.get("collective_bytes", 0.0) for s in classes.values())
        assert coll == two["collective_bytes"]
        assert classes["collective"]["collective_bytes"] == coll
        assert classes["collective"]["count"] > 0
        for key in ("flops", "hbm_bytes"):
            assert sum(s.get(key, 0.0) for s in classes.values()) == two[key]

        # records stamp the device fingerprint the fit-time guard checks
        from repro.engine.devices import get_device
        assert two["device_fingerprint"] == get_device("host_cpu").fingerprint()
        print("OK", two["collective_bytes"])
    """, n_devices=2)


def test_elastic_checkpoint_restore_different_mesh(tmp_path):
    _run(f"""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.launch.mesh import make_mesh
        from repro.train import checkpoint as ckpt

        d = {str(tmp_path)!r}
        state = {{"w": np.arange(64, dtype=np.float32).reshape(8, 8)}}
        mesh1 = make_mesh((4, 2), ("data", "model"))
        sharded = jax.device_put(state["w"], NamedSharding(mesh1, P("data", "model")))
        ckpt.save_checkpoint(d, 3, {{"w": sharded}})

        mesh2 = make_mesh((2, 4), ("data", "model"))
        step, restored = ckpt.restore_checkpoint(
            d, template=state,
            shardings={{"w": NamedSharding(mesh2, P("data", "model"))}})
        assert step == 3
        np.testing.assert_array_equal(np.asarray(restored["w"]), state["w"])
        assert restored["w"].sharding.mesh.devices.shape == (2, 4)
        print("OK")
    """)
