"""Optional-``hypothesis`` shim for the test suite.

Property-based tests use hypothesis when it is installed; without it they
collect as skipped stubs instead of breaking collection of the whole module
(the tier-1 suite must run on a bare jax+numpy+pytest environment).

Usage (drop-in for the real import)::

    from tests._hypothesis import given, settings, st
"""

from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:  # pragma: no cover - exercised on bare envs
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def stub():
                pass

            stub.__name__ = fn.__name__
            stub.__doc__ = fn.__doc__
            return stub

        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _AnyStrategy:
        """Accepts any strategy-construction call and returns a placeholder."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()
