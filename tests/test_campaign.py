"""Campaign subsystem: planning, resumable running, fitting, engine wiring.

Most tests drive the runner with a deterministic fake measurement (no jax,
milliseconds); one smoke test compiles a real 4-cell host-CPU grid end to
end (tier-1: small enough to stay out of the slow marker)."""

from __future__ import annotations

import json
import os

import numpy as np
import pytest

from repro.campaign import (
    CampaignCell,
    CampaignLedger,
    CampaignRunner,
    LMForest,
    fit_hlo_constants,
    fit_lm_forest,
    plan_grid,
    register_lm_forest,
    smoke_plan,
)
from repro.campaign.plan import SMOKE_SHAPES, load_plan, mesh_dims
from repro.core.fileio import append_jsonl, load_jsonl_tolerant
from repro.engine import CostEngine, CostQuery
from repro.engine.backends import AnalyticalBackend, EnsembleBackend, ForestBackend


def fake_measure(cell: CampaignCell) -> dict:
    """Deterministic ground-truth stand-in: targets are smooth functions of
    the cell geometry, so forests have signal and re-runs are bit-equal."""
    t = cell.shape.tokens
    train = cell.shape.kind == "train"
    flops = 1e6 * t * (3.0 if train else 1.0)
    hbm = 2e5 * t
    mm_bytes = 0.5 * hbm  # exact halving: the two classes re-sum bit-exactly
    return {
        "gamma_mb": 8.0 + 0.02 * t + (4.0 if train else 0.0),
        "phi_ms": 1.0 + 0.004 * t * (3.0 if train else 1.0),
        "compile_s": 0.0,
        "flops": flops,
        "hbm_bytes": hbm,
        "collective_bytes": 0.0,
        "cost_classes": {
            "matmul": {"flops": flops, "hbm_bytes": mm_bytes,
                       "collective_bytes": 0.0, "count": 4},
            "elementwise": {"flops": 0.0, "hbm_bytes": hbm - mm_bytes,
                            "collective_bytes": 0.0, "count": 9},
        },
        "temp_mb": 1.0, "arg_mb": 1.0, "n_devices": 1, "executed": True,
    }


def run_fake_campaign(plan, ledger_path, **kw):
    runner = CampaignRunner(plan, ledger_path, measure=fake_measure, **kw)
    return runner, runner.run_campaign()


# ---------------------------------------------------------------------------
# fileio: the durable-append ledger contract
# ---------------------------------------------------------------------------


class TestJsonlFileio:
    def test_roundtrip_and_append(self, tmp_path):
        p = str(tmp_path / "l.jsonl")
        assert load_jsonl_tolerant(p) == []
        append_jsonl(p, {"a": 1})
        append_jsonl(p, [{"b": 2}, {"c": 3}])
        assert load_jsonl_tolerant(p) == [{"a": 1}, {"b": 2}, {"c": 3}]

    def test_torn_final_line_dropped(self, tmp_path):
        p = str(tmp_path / "l.jsonl")
        append_jsonl(p, [{"a": 1}, {"b": 2}])
        with open(p, "a") as f:
            f.write('{"torn": tru')  # crash mid-append
        assert load_jsonl_tolerant(p) == [{"a": 1}, {"b": 2}]
        # and appends after the torn line still parse (new line boundary)
        append_jsonl(p, {"d": 4})
        recs = load_jsonl_tolerant(p)
        assert {"d": 4} in recs and len(recs) == 3

    def test_non_dict_rows_ignored(self, tmp_path):
        p = str(tmp_path / "l.jsonl")
        with open(p, "w") as f:
            f.write('[1,2]\n"str"\n{"ok": 1}\n\n')
        assert load_jsonl_tolerant(p) == [{"ok": 1}]


# ---------------------------------------------------------------------------
# plan
# ---------------------------------------------------------------------------


class TestPlan:
    def test_reproducible_hash(self):
        a = smoke_plan(subsample=4, seed=7)
        b = smoke_plan(subsample=4, seed=7)
        assert a.plan_hash == b.plan_hash
        assert [c.key for c in a.cells] == [c.key for c in b.cells]
        assert a.plan_hash != smoke_plan(subsample=4, seed=8).plan_hash

    def test_stratified_subsample_covers_archs(self):
        plan = smoke_plan(subsample=4, seed=0)
        assert {c.arch for c in plan.cells} == {"qwen3-4b", "stablelm-1.6b"}

    def test_unsupported_cells_skipped(self):
        # batch 2 cannot split over 4 data-parallel devices
        plan = plan_grid(archs=("qwen3-4b",), shapes=("smoke_train_16x2",),
                         meshes=("4x1",))
        assert len(plan.cells) == 0
        assert plan.skipped and "not divisible" in plan.skipped[0]["why"]

    def test_save_load_and_tamper_detection(self, tmp_path):
        plan = smoke_plan(subsample=3, seed=0)
        p = str(tmp_path / "plan.json")
        plan.save(p)
        loaded = load_plan(p)
        assert loaded.plan_hash == plan.plan_hash
        assert loaded.cells == plan.cells
        blob = json.load(open(p))
        blob["cells"] = blob["cells"][1:]
        json.dump(blob, open(p, "w"))
        with pytest.raises(ValueError, match="inconsistent"):
            load_plan(p)

    def test_mesh_dims(self):
        assert mesh_dims("2x16x16") == (2, 16, 16)
        with pytest.raises(ValueError):
            mesh_dims("banana")


# ---------------------------------------------------------------------------
# runner: resume semantics (the satellite's kill/restart contract)
# ---------------------------------------------------------------------------


class TestRunnerResume:
    def test_interrupted_run_resumes_without_remeasuring(self, tmp_path):
        plan = smoke_plan(subsample=6, seed=0)
        led = str(tmp_path / "ledger.jsonl")
        calls: list[str] = []

        def counting(cell):
            calls.append(cell.key)
            return fake_measure(cell)

        # "kill" the first runner after 2 cells
        r1 = CampaignRunner(plan, led, measure=counting)
        out1 = r1.run_campaign(max_cells=2)
        assert out1["measured"] == 2 and out1["remaining"] == len(plan) - 2

        # a crash can also tear the in-flight record — simulate it
        with open(led, "a") as f:
            f.write('{"key": "half-writ')

        # fresh process: new runner over the same ledger file
        r2 = CampaignRunner(plan, led, measure=counting)
        out2 = r2.run_campaign()
        assert out2["measured"] == len(plan) - 2
        assert out2["remaining"] == 0
        # no cell measured twice across the kill/restart
        assert len(calls) == len(set(calls)) == len(plan)

        # third run: everything recorded, zero work
        _, out3 = run_fake_campaign(plan, led)
        assert out3["measured"] == 0 and out3["failed"] == 0

    def test_final_ledger_equals_uninterrupted_run(self, tmp_path):
        plan = smoke_plan(subsample=6, seed=0)
        a, b = str(tmp_path / "a.jsonl"), str(tmp_path / "b.jsonl")
        # interrupted in three slices vs one uninterrupted pass
        for max_cells in (2, 3, None):
            CampaignRunner(plan, a, measure=fake_measure).run_campaign(
                max_cells=max_cells)
        run_fake_campaign(plan, b)
        rec_a = {r["key"]: r for r in CampaignLedger(a).records()}
        rec_b = {r["key"]: r for r in CampaignLedger(b).records()}
        assert rec_a == rec_b

    def test_quarantine_persists_and_is_not_retried(self, tmp_path):
        plan = smoke_plan(subsample=6, seed=0)
        led = str(tmp_path / "ledger.jsonl")
        poison = plan.cells[2].key
        attempts: list[str] = []

        def flaky(cell):
            attempts.append(cell.key)
            if cell.key == poison:
                raise RuntimeError("unlowerable layout")
            return fake_measure(cell)

        r1 = CampaignRunner(plan, led, measure=flaky)
        out1 = r1.run_campaign()
        assert out1["failed"] == 1 and out1["remaining"] == 0
        assert CampaignLedger(led).failed_keys == {poison}
        rec = CampaignLedger(led).get(poison)
        assert rec["status"] == "failed" and "unlowerable" in rec["error"]

        # restart: quarantined cell is NOT re-attempted...
        r2 = CampaignRunner(plan, led, measure=flaky)
        assert r2.run_campaign()["measured"] == 0
        assert attempts.count(poison) == 1
        # ...unless explicitly asked
        r3 = CampaignRunner(plan, led, measure=fake_measure, retry_failed=True)
        assert r3.run_campaign()["measured"] == 1
        assert CampaignLedger(led).failed_keys == set()

    def test_hung_cell_quarantined_as_timeout(self, tmp_path):
        """A measurement that hangs (not raises) is fenced by the
        per-cell wall-clock budget and quarantined as error:"timeout" —
        the campaign moves on instead of stalling forever."""
        import threading

        plan = smoke_plan(subsample=4, seed=0)
        led = str(tmp_path / "ledger.jsonl")
        hung_key = plan.cells[1].key
        release = threading.Event()

        def hang(cell):
            if cell.key == hung_key:
                release.wait(30.0)      # "compile that never returns"
            return fake_measure(cell)

        runner = CampaignRunner(plan, led, measure=hang, cell_timeout_s=0.2)
        out = runner.run_campaign()
        release.set()                   # unstick the abandoned thread
        assert out["measured"] == len(plan) - 1
        assert out["failed"] == 1 and out["remaining"] == 0
        rec = CampaignLedger(led).get(hung_key)
        assert rec["status"] == "failed" and rec["error"] == "timeout"
        # quarantine semantics hold: not retried on restart
        r2 = CampaignRunner(plan, led, measure=fake_measure,
                            cell_timeout_s=0.2)
        assert r2.run_campaign()["measured"] == 0
        # ...and a fast measurement under the same fence is untouched
        r3 = CampaignRunner(plan, led, measure=fake_measure,
                            cell_timeout_s=0.2, retry_failed=True)
        assert r3.run_campaign()["measured"] == 1
        assert CampaignLedger(led).failed_keys == set()

    def test_shards_partition_the_grid(self, tmp_path):
        plan = smoke_plan(seed=0)  # all 16 cells
        led = str(tmp_path / "ledger.jsonl")
        runner = CampaignRunner(plan, led, measure=fake_measure)
        shards = [runner.shard_cells(i, 3) for i in range(3)]
        keys = [c.key for s in shards for c in s]
        assert sorted(keys) == sorted(c.key for c in plan.cells)
        # two workers, one shared ledger: disjoint work, union complete
        CampaignRunner(plan, led, measure=fake_measure).run_campaign(0, 2)
        CampaignRunner(plan, led, measure=fake_measure).run_campaign(1, 2)
        assert CampaignLedger(led).ok_keys == {c.key for c in plan.cells}


# ---------------------------------------------------------------------------
# fit: forests, constants, persistence
# ---------------------------------------------------------------------------


def _fitted(tmp_path, n=12):
    plan = smoke_plan(subsample=n, seed=0)
    led = str(tmp_path / "ledger.jsonl")
    runner, _ = run_fake_campaign(plan, led)
    records = runner.ledger.records("ok")
    return records, fit_lm_forest(records, holdout_frac=0.25, seed=0)


class TestFit:
    def test_forest_learns_the_fake_grid(self, tmp_path):
        records, forest = _fitted(tmp_path)
        assert forest.fitted
        assert forest.meta["n_heldout"] >= 1
        # the fake targets are smooth in the features: held-out error small
        assert forest.meta["holdout_phi_mape"] < 0.5
        assert forest.meta["holdout_gamma_mape"] < 0.5

    def test_save_load_roundtrip(self, tmp_path):
        records, forest = _fitted(tmp_path)
        q = [CostQuery(arch="qwen3-4b", bs=2, seq=32, stage="train")]
        want = forest.predict_queries(q)
        for ext in ("npz", "json"):
            path = str(tmp_path / f"forest.{ext}")
            forest.save(path)
            loaded = LMForest.load(path)
            got = loaded.predict_queries(q)
            np.testing.assert_allclose(got[0], want[0])
            np.testing.assert_allclose(got[1], want[1])
            assert loaded.meta["plan_hash"] == forest.meta["plan_hash"]
            assert loaded.content_hash() == forest.content_hash()

    def test_feature_drift_detected_on_load(self, tmp_path):
        records, forest = _fitted(tmp_path)
        path = str(tmp_path / "forest.json")
        forest.save(path)
        blob = json.load(open(path))
        blob["feature_names"] = blob["feature_names"][:-1]
        json.dump(blob, open(path, "w"))
        with pytest.raises(ValueError, match="different feature set"):
            LMForest.load(path)

    def test_hlo_constants_recovered(self, tmp_path):
        # synthetic records with KNOWN roofline constants: the NNLS must
        # invert them (phi = c0 + flops/peak + bytes/bw, no collectives)
        peak, bw, c0 = 2e9, 5e8, 3e-3
        rng = np.random.default_rng(0)
        records = []
        for i in range(10):
            fl = float(rng.uniform(1e6, 1e8))
            hb = float(rng.uniform(1e5, 1e7))
            records.append({
                "status": "ok", "device": "host_cpu", "plan_hash": "x",
                "flops": fl, "hbm_bytes": hb, "collective_bytes": 0.0,
                "phi_ms": (c0 + fl / peak + hb / bw) * 1e3,
            })
        spec = fit_hlo_constants(records)
        assert spec.calibrated and spec.combine == "sum"
        assert spec.peak_flops == pytest.approx(peak, rel=1e-4)
        assert spec.hbm_bw == pytest.approx(bw, rel=1e-4)
        assert spec.launch_overhead_s == pytest.approx(c0, rel=1e-4)
        assert spec.meta["phi_mape"] < 1e-6

    def test_register_walks_engine_and_ensemble(self, tmp_path):
        records, forest = _fitted(tmp_path)
        fb = ForestBackend()
        engine = CostEngine(EnsembleBackend([fb, AnalyticalBackend()]))
        owner = register_lm_forest(engine, forest)
        assert owner is fb and fb.lm is forest
        with pytest.raises(ValueError):
            register_lm_forest(EnsembleBackend([AnalyticalBackend()]), forest)


# ---------------------------------------------------------------------------
# satellite: fit-time device-fingerprint guard
# ---------------------------------------------------------------------------


class TestFingerprintGuard:
    def _records(self, tmp_path, fingerprint=None):
        plan = smoke_plan(subsample=8, seed=0)
        runner, _ = run_fake_campaign(plan, str(tmp_path / "l.jsonl"))
        records = runner.ledger.records("ok")
        if fingerprint is not None:
            for r in records:
                r["device_fingerprint"] = fingerprint
        return records

    def test_matching_fingerprints_pass(self, tmp_path):
        from repro.campaign.fit import check_device_fingerprints
        from repro.engine.devices import get_device

        records = self._records(tmp_path,
                                get_device("host_cpu").fingerprint())
        out = check_device_fingerprints(records)
        assert out == {"checked": len(records), "unstamped": 0,
                       "mismatched": 0}
        forest = fit_lm_forest(records, holdout_frac=0.25, seed=0)
        assert forest.meta["fingerprint_check"]["mismatched"] == 0

    def test_unstamped_legacy_records_pass(self, tmp_path):
        from repro.campaign.fit import check_device_fingerprints

        records = self._records(tmp_path)  # fake_measure stamps nothing
        out = check_device_fingerprints(records)
        assert out["unstamped"] == len(records) and out["checked"] == 0
        assert fit_lm_forest(records, holdout_frac=0.25, seed=0).fitted

    def test_stale_fingerprint_refused(self, tmp_path):
        records = self._records(tmp_path, "deadbeefdeadbeef")
        with pytest.raises(ValueError, match="different device constants"):
            fit_lm_forest(records, holdout_frac=0.25, seed=0)
        with pytest.raises(ValueError, match="different device constants"):
            fit_hlo_constants(records)

    def test_allow_mixed_opts_in(self, tmp_path):
        records = self._records(tmp_path, "deadbeefdeadbeef")
        forest = fit_lm_forest(records, holdout_frac=0.25, seed=0,
                               allow_mixed=True)
        assert forest.fitted
        assert forest.meta["fingerprint_check"]["mismatched"] == len(records)
        assert fit_hlo_constants(records, allow_mixed=True).calibrated

    def test_device_override_trips_the_guard(self, tmp_path):
        """Re-featurizing a campaign under another spec is exactly the
        mismatch the guard exists for: explicit --allow-mixed required."""
        from repro.engine.devices import get_device

        records = self._records(tmp_path,
                                get_device("host_cpu").fingerprint())
        with pytest.raises(ValueError, match="different device constants"):
            fit_lm_forest(records, device="tpu_v5e", holdout_frac=0.25,
                          seed=0)
        forest = fit_lm_forest(records, device="tpu_v5e", holdout_frac=0.25,
                               seed=0, allow_mixed=True)
        assert forest.meta["device"] == "tpu_v5e"

    def test_mixed_device_ledger_refused_for_hlo_fit(self):
        """One NNLS system fits ONE device; a fleet ledger must be
        filtered (or explicitly allow_mixed) even when every record's
        fingerprint matches its own device."""
        rng = np.random.default_rng(0)
        records = []
        for i in range(8):
            records.append({
                "status": "ok", "plan_hash": "x",
                "device": "host_cpu" if i % 2 else "tpu_v5e",
                "flops": float(rng.uniform(1e6, 1e8)),
                "hbm_bytes": float(rng.uniform(1e5, 1e7)),
                "collective_bytes": 0.0, "phi_ms": 1.0 + i,
            })
        with pytest.raises(ValueError, match="one device"):
            fit_hlo_constants(records)
        assert fit_hlo_constants(records, allow_mixed=True).calibrated
        # single-device ledgers are unaffected
        for r in records:
            r["device"] = "host_cpu"
        assert fit_hlo_constants(records).calibrated

    def test_cli_allow_mixed_flag(self, tmp_path, monkeypatch):
        from repro.campaign import __main__ as cli
        from repro.engine.devices import get_device

        plan_path = str(tmp_path / "plan.json")
        assert cli.main(["plan", "--smoke", "--subsample", "6",
                         "--out", plan_path]) == 0
        led = str(tmp_path / "ledger.jsonl")
        monkeypatch.setattr(
            "repro.campaign.runner.measure_cell",
            lambda cell, **kw: {**fake_measure(cell),
                                "device_fingerprint": "stale00stale00"})
        assert cli.main(["run", "--plan", plan_path, "--ledger", led]) == 0
        out_path = str(tmp_path / "forest.json")
        with pytest.raises(ValueError, match="--allow-mixed"):
            cli.main(["fit", "--ledger", led, "--out", out_path])
        assert cli.main(["fit", "--ledger", led, "--out", out_path,
                         "--allow-mixed"]) == 0
        assert os.path.exists(out_path)


# ---------------------------------------------------------------------------
# engine integration: zero compiles through the fitted forest
# ---------------------------------------------------------------------------


class TestZeroCompileAdmission:
    def test_admit_lm_cell_without_compiling(self, tmp_path, monkeypatch):
        records, forest = _fitted(tmp_path)

        import jax

        import repro.launch.lowering as lowering

        def boom(*a, **k):
            raise AssertionError("admission path invoked the jax compiler")

        monkeypatch.setattr(jax, "jit", boom)
        monkeypatch.setattr(lowering, "compile_cell", boom)
        monkeypatch.setattr(AnalyticalBackend, "_compile_arch", boom)

        engine = CostEngine(EnsembleBackend(
            [ForestBackend(lm=forest), AnalyticalBackend()]))
        ok, info = engine.admit(
            CostQuery(arch="stablelm-1.6b", bs=2, seq=64, stage="train"),
            gamma_budget_mb=1e6)
        assert ok and info["source"] == "forest"
        # batched path, infer stage, and an arch outside the campaign also
        # answer compile-free (featurization generalizes over the registry)
        ests = engine.backend.estimate([
            CostQuery(arch="qwen3-4b", bs=4, seq=32, stage="infer"),
            CostQuery(arch="internlm2-1.8b", bs=2, seq=16, stage="train"),
        ])
        assert all(e.source == "forest" and e.detail.get("lm") for e in ests)

    def test_unfitted_forest_falls_through(self):
        backend = ForestBackend()  # no CNN predictors, no LM forest
        assert not backend.supports(
            CostQuery(arch="qwen3-4b", bs=2, stage="train"))

    def test_cache_salt_tracks_lm_forest(self, tmp_path):
        records, forest = _fitted(tmp_path)
        empty = ForestBackend()
        with_lm = ForestBackend(lm=forest)
        assert empty.cache_salt() != with_lm.cache_salt()


# ---------------------------------------------------------------------------
# satellite: timed autotuner winners feed the calibration fit
# ---------------------------------------------------------------------------


class TestTimedWinnersCalibration:
    def _dps(self, peak, bw, n=8, seed=0):
        from repro.core.dataset import Datapoint
        from repro.core.features import FEATURE_NAMES
        from repro.engine.decompose import latency_terms, memory_terms

        rng = np.random.default_rng(seed)
        dps = []
        for i in range(n):
            f = rng.uniform(1e3, 1e6, size=len(FEATURE_NAMES))
            flops, byts = latency_terms(f, 4)
            w, a = memory_terms(f, 4)
            dps.append(Datapoint(
                family="synthetic", level=0.1 * i, strategy="random", bs=2,
                width_mult=0.25, input_hw=16, seed=0,
                gamma_mb=float(5 + w[0] / 1e6 + a[0] / 1e6),
                phi_ms=float((flops[0] / peak + byts[0] / bw) * 1e3),
                features=[float(v) for v in f]))
        return dps

    def _timed_cache(self, tmp_path, measured_us):
        from repro.kernels.autotune import TuningCache
        from repro.kernels.flash_attention import tiling

        shape = tiling.shape_key((1, 2, 256, 64), (1, 2, 256, 64),
                                 causal=True, dtype="bfloat16")
        cache = TuningCache(str(tmp_path / "tuning.json"))
        cache.put("k1", {"kernel": "flash_attention", "shape": shape,
                         "config": tiling.default(shape), "source": "timed",
                         "measured_us": measured_us})
        # model-ranked and shape-less entries must be ignored
        cache.put("k2", {"kernel": "flash_attention", "shape": shape,
                         "config": tiling.default(shape), "source": "model",
                         "model_us": 1.0})
        cache.put("k3", {"kernel": "flash_attention", "source": "timed",
                         "config": {}, "measured_us": 5.0})
        return cache

    def test_fit_consumes_timed_rows(self, tmp_path):
        from repro.engine.calibrate import calibrate, timed_tuning_rows

        cache = self._timed_cache(tmp_path, measured_us=500.0)
        A, phi = timed_tuning_rows(cache)
        assert A.shape == (1, 3) and phi.shape == (1,)
        assert phi[0] == pytest.approx(500e-6)

        dps = self._dps(peak=1e10, bw=1e9)
        backend = AnalyticalBackend()
        base = calibrate(backend, None, [], datapoints=dps, apply=False)
        fed = calibrate(backend, None, [], datapoints=dps,
                        tuning_cache=cache, apply=False)
        assert base.meta["n_timed_kernel_rows"] == 0
        assert fed.meta["n_timed_kernel_rows"] == 1
        # the kernel row disagrees with the synthetic grid's constants, so
        # consuming it must move the fit
        assert fed.peak_flops != pytest.approx(base.peak_flops, rel=1e-6)

    def test_empty_cache_is_noop(self, tmp_path):
        from repro.engine.calibrate import timed_tuning_rows
        from repro.kernels.autotune import TuningCache

        A, phi = timed_tuning_rows(TuningCache(str(tmp_path / "t.json")))
        assert len(phi) == 0


# ---------------------------------------------------------------------------
# satellite: MoE dispatch autotuning
# ---------------------------------------------------------------------------


class TestMoeDispatchTuning:
    SHAPE = dict(B=4, S=32, D=128, E=4, K=2, F=128)

    def test_capacity_formula_matches_moe_block(self):
        from repro.kernels.moe_dispatch.tiling import _capacity
        from repro.models.layers import moe_capacity

        for tok in (8, 17, 64, 1000):
            for E, K, f in ((4, 2, 1.25), (8, 1, 1.0), (64, 8, 2.0)):
                assert _capacity(tok, E, K, f) == moe_capacity(tok, E, K, f)

    def test_default_in_candidates_and_tuned_never_worse(self):
        from repro.kernels.autotune import KernelTuner
        from repro.kernels.moe_dispatch import tiling

        shape = tiling.shape_key(**self.SHAPE, capacity_factor=1.25,
                                 dtype="bfloat16")
        assert tiling.default(shape) in tiling.candidates(shape)
        tuner = KernelTuner(device="tpu_v5e", measure=False)
        entry = tuner.explain("moe_dispatch", shape)
        assert entry["model_us"] <= entry["default_model_us"] * (1 + 1e-9)

    def test_candidates_never_below_configured_capacity(self):
        from repro.kernels.moe_dispatch import tiling

        shape = tiling.shape_key(**self.SHAPE, capacity_factor=1.5,
                                 dtype="bfloat16")
        assert all(c["capacity_factor"] >= 1.5 - 1e-9
                   for c in tiling.candidates(shape))

    def test_moe_block_uses_tuned_groups(self, monkeypatch):
        from repro.models import layers

        seen = {}

        def fake_tuned(kernel, shape, default=None):
            seen["kernel"] = kernel
            return {"groups": 2, "capacity_factor": 1.0}  # below configured!

        import repro.kernels.autotune as at

        monkeypatch.setattr(at, "tuned_config", fake_tuned)

        class Cfg:
            d_model, n_experts, experts_per_token = 128, 4, 2
            moe_d_ff_, capacity_factor = 128, 1.25

        g, f = layers._tuned_moe_dispatch(4, 32, Cfg, "bfloat16")
        assert seen["kernel"] == "moe_dispatch"
        assert g == 2
        assert f == 1.25  # clamped back up: quality knob never tightened


# ---------------------------------------------------------------------------
# satellite: dryrun --out ledger dedupe
# ---------------------------------------------------------------------------


class TestDryrunLedger:
    def test_recorded_cells_dedupe_and_tolerate_torn_lines(self, tmp_path):
        from repro.launch.dryrun import _cell_id, _recorded_cells

        p = str(tmp_path / "dryrun.jsonl")
        append_jsonl(p, [
            {"arch": "a", "shape": "s", "mesh": "16x16", "step_s": 1.0},
            {"arch": "a", "shape": "s", "mesh": "16x16", "step_s": 2.0},  # re-run
            {"arch": "b", "shape": "s", "mesh": "16x16", "skipped": "why"},
            {"unrelated": True},
        ])
        with open(p, "a") as f:
            f.write('{"arch": "c", "shape": "torn"')
        cells = _recorded_cells(p)
        assert cells == {_cell_id("a", "s", "16x16"), _cell_id("b", "s", "16x16")}
        assert _recorded_cells(None) == set()


# ---------------------------------------------------------------------------
# the real thing: 4-cell host-CPU grid, compiled and timed (tier-1 smoke)
# ---------------------------------------------------------------------------


class TestCampaignSmoke:
    def test_four_cell_grid_end_to_end(self, tmp_path):
        plan = smoke_plan(
            archs=("qwen3-4b",),
            shapes=("smoke_train_16x2", "smoke_train_32x2",
                    "smoke_prefill_32x2", "smoke_prefill_64x2"),
        )
        assert len(plan) == 4
        led = str(tmp_path / "ledger.jsonl")
        runner = CampaignRunner(plan, led, repeats=1, warmup=1)
        out = runner.run_campaign()
        assert out["measured"] == 4 and out["failed"] == 0

        records = runner.ledger.records("ok")
        for r in records:
            assert r["phi_ms"] > 0 and r["gamma_mb"] > 0
            assert r["flops"] > 0 and r["hbm_bytes"] > 0
            assert r["executed"] and r["n_devices"] == 1

        # resume over the real ledger: nothing recompiles
        assert CampaignRunner(plan, led).run_campaign()["measured"] == 0

        # fit + one zero-compile admission over the real ground truth
        forest = fit_lm_forest(records, holdout_frac=0.0, seed=0)
        engine = CostEngine(ForestBackend(lm=forest))
        ok, info = engine.admit(
            CostQuery(arch="qwen3-4b", bs=2, seq=16, stage="train"),
            gamma_budget_mb=1e5)
        assert ok and info["source"] == "forest"
        # in-sample prediction of a measured cell is in the right ballpark
        r16 = next(r for r in records if r["shape"]["name"] == "smoke_train_16x2")
        est = engine.estimate_one(
            CostQuery(arch="qwen3-4b", bs=2, seq=16, stage="train"))
        assert est.gamma_mb == pytest.approx(r16["gamma_mb"], rel=0.75)


# ---------------------------------------------------------------------------
# CLI (plan/status only — run/fit covered above without subprocess cost)
# ---------------------------------------------------------------------------


class TestCli:
    def test_plan_run_status_fit(self, tmp_path, capsys, monkeypatch):
        from repro.campaign import __main__ as cli

        plan_path = str(tmp_path / "plan.json")
        assert cli.main(["plan", "--smoke", "--subsample", "4",
                         "--out", plan_path]) == 0
        plan = load_plan(plan_path)

        led = str(tmp_path / "ledger.jsonl")
        monkeypatch.setattr(
            "repro.campaign.runner.measure_cell",
            lambda cell, **kw: fake_measure(cell))
        assert cli.main(["run", "--plan", plan_path, "--ledger", led]) == 0
        assert cli.main(["status", "--plan", plan_path, "--ledger", led]) == 0
        out_json = capsys.readouterr().out
        assert '"pending": 0' in out_json

        # per-op-class breakdown view over the recorded ledgers
        assert cli.main(["status", "--ledger", led, "--breakdown"]) == 0
        breakdown = json.loads(capsys.readouterr().out)["breakdown"]
        assert breakdown["records_with_breakdown"] == 4
        assert breakdown["classes"]["matmul"]["flops_share"] == 1.0
        assert 0 < breakdown["classes"]["elementwise"]["hbm_share"] < 1

        forest_path = str(tmp_path / "forest.npz")
        assert cli.main(["fit", "--ledger", led, "--out", forest_path,
                         "--holdout", "0.25"]) == 0
        assert os.path.exists(forest_path)
        assert LMForest.load(forest_path).fitted
