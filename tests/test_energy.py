"""Energy as a first-class predicted cost attribute (ISSUE 7).

Covers the whole chain: envelope pricing (watts proxy, per-op dynamic
joules, the bit-identical ledger parity contract), planted-coefficient
NNLS recovery on the CNN calibration and LM campaign paths (aggregate AND
class-wise), the fitted forest → analytical energy path with zero jax
compiles, energy-budget admission carrying the per-class breakdown, and
the DeviceSpec power envelope (modes, fingerprint, persistence)."""

import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.core.features import FEATURE_NAMES
from repro.costmodel import CostLedger, OpCost
from repro.engine import (
    AnalyticalBackend,
    CostEngine,
    CostEstimate,
    CostQuery,
    EnsembleBackend,
    ForestBackend,
    get_device,
)
from repro.engine.decompose import (
    CNN_LATENCY_COLUMNS,
    classwise_seconds,
    cnn_energy_class_joules,
    energy_terms,
    latency_class_columns,
    latency_terms,
    ledger_latency_columns,
    lm_roofline_terms,
    price_ledger_energy,
    watts_proxy,
)
from repro.engine.devices import (
    DeviceSpec,
    load_device_spec,
    save_device_spec,
)


def _pow2_device():
    """Every pricing multiplier an exact power of two (dyn = 16 W), so
    per-record energies are dyadic rationals and grouped vs sequential
    sums are EXACTLY equal — the bit-identical parity contract."""
    return DeviceSpec(name="pow2", peak_flops=2.0**40, hbm_bw=2.0**33,
                      ici_bw=2.0**30, idle_w=2.0, peak_w=18.0)


def _ledger(n=64, seed=7):
    rng = np.random.default_rng(seed)
    classes = ("matmul", "elementwise", "collective", "data_movement")
    return CostLedger([
        OpCost(op=f"op{i}", op_class=classes[i % 4],
               flops=float(rng.integers(1, 2**20)) * 2.0**10,
               hbm_bytes=float(rng.integers(1, 2**20)) * 2.0**8,
               collective_bytes=float(rng.integers(0, 2**10)) * 2.0**8)
        for i in range(n)
    ])


# ---------------------------------------------------------------------------
# ledger energy: per-op pricing + bit-identical class-sum parity
# ---------------------------------------------------------------------------


class TestLedgerEnergyParity:
    def test_class_sums_resum_bit_identical(self):
        dev = _pow2_device()
        led = price_ledger_energy(_ledger(), dev)
        sums = led.class_sums()
        assert sum(s["energy_j"] for s in sums.values()) == led.energy_j
        assert led.totals()["energy_j"] == led.energy_j
        # per-record pricing is the exact three-term product
        dyn = dev.dynamic_w
        r = led.records[0]
        assert r.energy_j == (r.flops * (dyn / dev.peak_flops)
                              + r.hbm_bytes * (dyn / dev.hbm_bw)
                              + r.collective_bytes * (dyn / dev.ici_bw))

    def test_scaled_and_merge_preserve_energy(self):
        led = price_ledger_energy(_ledger(), _pow2_device())
        assert led.scaled(2.0).energy_j == 2.0 * led.energy_j
        merged = CostLedger.merge_class_sums(
            [led.class_sums(), led.class_sums()])
        assert sum(s["energy_j"] for s in merged.values()) \
            == 2.0 * led.energy_j

    def test_npz_roundtrip_keeps_energy(self, tmp_path):
        led = price_ledger_energy(_ledger(8), _pow2_device())
        p = str(tmp_path / "led.npz")
        led.save(p)
        back = CostLedger.load(p)
        assert [r.energy_j for r in back.records] \
            == [r.energy_j for r in led.records]

    def test_zero_watt_device_prices_zero(self):
        led = price_ledger_energy(
            _ledger(8), DeviceSpec(name="inert", peak_flops=1e12,
                                   hbm_bw=1e11))
        assert led.energy_j == 0.0


# ---------------------------------------------------------------------------
# envelope pricing: watts proxy + analytical energy terms
# ---------------------------------------------------------------------------


class TestEnvelope:
    def test_watts_proxy_bounds_and_clamps(self):
        dev = get_device("tx2_like")
        # fully compute-bound: utilisation clamps at 1 → peak watts
        assert float(watts_proxy(dev.peak_flops * 10.0, 1.0, dev)) \
            == pytest.approx(dev.peak_w)
        # no flops → idle draw; phi=0 (compile-only cell) → idle draw
        assert float(watts_proxy(0.0, 1.0, dev)) == pytest.approx(dev.idle_w)
        assert float(watts_proxy(1e9, 0.0, dev)) == pytest.approx(dev.idle_w)
        mid = float(watts_proxy(dev.peak_flops * 0.5, 1.0, dev))
        assert dev.idle_w < mid < dev.peak_w

    def test_energy_terms_are_dyn_scaled_roofline(self):
        dev = get_device("tx2_like")
        static, comp, mem, coll = energy_terms(
            1e12, 1e9, 0.5, dev, collective_bytes=1e6)
        c_s, m_s, co_s = lm_roofline_terms(1e12, 1e9, 1e6, dev)
        assert float(static) == pytest.approx(dev.idle_w * 0.5)
        assert float(comp) == pytest.approx(dev.dynamic_w * float(c_s))
        assert float(mem) == pytest.approx(dev.dynamic_w * float(m_s))
        assert float(coll) == pytest.approx(dev.dynamic_w * float(co_s))

    def test_cnn_energy_class_joules_resum_to_aggregate(self):
        rng = np.random.default_rng(0)
        f = rng.uniform(1e3, 1e6, size=len(FEATURE_NAMES))
        dev = _pow2_device()
        cls_j = cnn_energy_class_joules(f, 4, dev)
        flops, bytes_moved = latency_terms(f, 4)
        total = sum(float(np.atleast_1d(v)[0]) for v in cls_j.values())
        agg = (float(np.atleast_1d(flops)[0]) * dev.dynamic_w
               / dev.peak_flops
               + float(np.atleast_1d(bytes_moved)[0]) * dev.dynamic_w
               / dev.hbm_bw)
        assert total == pytest.approx(agg, rel=1e-12)


# ---------------------------------------------------------------------------
# planted-coefficient recovery: CNN calibration path
# ---------------------------------------------------------------------------


def _cnn_dps(planted_energy, seed=0, n=10):
    """Synthetic datapoints with measured energy built from a callable of
    the class columns (the same decomposition the fit solves over)."""
    from repro.core.dataset import Datapoint

    rng = np.random.default_rng(seed)
    dps = []
    for i in range(n):
        f = rng.uniform(1e3, 1e6, size=len(FEATURE_NAMES))
        cols = latency_class_columns(f, 4)
        dps.append(Datapoint(
            family="synthetic", level=0.1 * i, strategy="random", bs=2,
            width_mult=0.25, input_hw=16, seed=0,
            gamma_mb=100.0, phi_ms=float(5.0 + 1e-9 * f.sum()),
            energy_j=float(planted_energy(
                {k: float(np.atleast_1d(v)[0]) for k, v in cols.items()})),
            features=[float(v) for v in f]))
    return dps


class TestCnnEnergyFit:
    def test_calibrate_recovers_planted_classwise_energy(self):
        from repro.engine.calibrate import calibrate

        e0, e_fl, e_ew, e_dm = 0.5, 2e-10, 6e-9, 4e-8
        dps = _cnn_dps(lambda c: e0 + e_fl * c["flops_matmul"]
                       + e_ew * c["hbm_elementwise"]
                       + e_dm * c["hbm_data_movement"])
        backend = AnalyticalBackend()
        spec = calibrate(backend, None, [], datapoints=dps, apply=True)
        assert spec.meta["energy_fit"] == "classwise"
        assert spec.meta["energy_mape"] < 1e-6
        # distinct byte costs: the tied aggregate genuinely cannot fit
        assert spec.meta["energy_mape_aggregate"] > spec.meta["energy_mape"]
        coeffs = spec.class_coeffs["cnn_energy"]
        assert coeffs["_intercept"] == pytest.approx(e0, rel=1e-3)
        assert coeffs["flops_matmul"] == pytest.approx(e_fl, rel=1e-3)
        assert coeffs["hbm_elementwise"] == pytest.approx(e_ew, rel=1e-3)
        assert coeffs["hbm_data_movement"] == pytest.approx(e_dm, rel=1e-3)

    def test_backend_prices_fitted_energy_with_resumming_breakdown(self):
        """The fitted spec's predictions: CostEstimate.energy_j equals the
        planted formula and detail["energy_classes"] re-sums to the
        aggregate minus the intercept — the column parity contract."""
        from repro.core.pruning import pruned_model
        from repro.engine.calibrate import calibrate

        e0, e_fl, e_ew, e_dm = 0.5, 2e-10, 6e-9, 4e-8
        dps = _cnn_dps(lambda c: e0 + e_fl * c["flops_matmul"]
                       + e_ew * c["hbm_elementwise"]
                       + e_dm * c["hbm_data_movement"])
        backend = AnalyticalBackend()
        calibrate(backend, None, [], datapoints=dps, apply=True)
        spec = pruned_model("squeezenet", 0.3, "random", seed=0,
                            width_mult=0.25, input_hw=16).conv_specs()
        est = backend.estimate([CostQuery(spec=spec, bs=8,
                                          stage="train")])[0]
        assert est.detail["energy_fit"] == "fitted"
        from repro.core.features import feature_matrix

        cols = latency_class_columns(
            feature_matrix([(spec, 8)])[0], backend.bytes_per_el)
        expected = e0 + sum(
            k * float(np.atleast_1d(cols[n])[0]) for k, n in
            zip((e_fl, e_ew, e_dm), CNN_LATENCY_COLUMNS))
        assert est.energy_j == pytest.approx(expected, rel=1e-3)
        assert sum(est.detail["energy_classes"].values()) \
            == pytest.approx(est.energy_j - e0, rel=1e-3)

    def test_uncalibrated_backend_envelope_energy_resums(self):
        """No fit anywhere: energy falls back to the power envelope, and
        the per-class breakdown still re-sums to the dynamic aggregate."""
        from repro.core.pruning import pruned_model

        backend = AnalyticalBackend(device="tx2_like")
        spec = pruned_model("squeezenet", 0.0, "random", seed=0,
                            width_mult=0.25, input_hw=16).conv_specs()
        est = backend.estimate([CostQuery(spec=spec, bs=4,
                                          stage="train")])[0]
        dev = backend.device
        assert est.detail["energy_fit"] == "envelope"
        assert est.energy_j > 0
        static_j = dev.idle_w * est.phi_ms / 1e3
        assert sum(est.detail["energy_classes"].values()) \
            == pytest.approx(est.energy_j - static_j, rel=1e-9)


# ---------------------------------------------------------------------------
# planted-coefficient recovery: LM campaign path
# ---------------------------------------------------------------------------


def _lm_records(planted_phi, planted_energy, seed=1, n=12):
    rng = np.random.default_rng(seed)
    records = []
    for _ in range(n):
        fl = float(rng.uniform(1e6, 1e8))
        ew = float(rng.uniform(1e5, 1e7))
        dm = float(rng.uniform(1e4, 1e6))
        classes = {
            "matmul": {"flops": fl, "hbm_bytes": 0.0,
                       "collective_bytes": 0.0, "count": 3},
            "elementwise": {"flops": 0.0, "hbm_bytes": ew,
                            "collective_bytes": 0.0, "count": 9},
            "data_movement": {"flops": 0.0, "hbm_bytes": dm,
                              "collective_bytes": 0.0, "count": 2},
        }
        records.append({
            "status": "ok", "device": "host_cpu", "plan_hash": "x",
            "flops": fl, "hbm_bytes": ew + dm, "collective_bytes": 0.0,
            "cost_classes": classes,
            "phi_ms": planted_phi(fl, ew, dm) * 1e3,
            "energy_j": planted_energy(fl, ew, dm),
        })
    return records


class TestLmEnergyFit:
    def test_fit_hlo_constants_recovers_planted_classwise_energy(self):
        from repro.campaign import fit_hlo_constants

        e0, e_mm, e_ew, e_dm = 0.2, 3e-12, 5e-9, 6e-8
        records = _lm_records(
            lambda fl, ew, dm: 1e-3 + fl / 2e9 + (ew + dm) / 5e8,
            lambda fl, ew, dm: e0 + e_mm * fl + e_ew * ew + e_dm * dm)
        spec = fit_hlo_constants(records)
        assert spec.meta["energy_fit"] == "classwise"
        assert spec.meta["energy_mape"] < 1e-6
        assert spec.meta["energy_mape_aggregate"] \
            > spec.meta["energy_mape"]
        coeffs = spec.class_coeffs["lm_energy"]
        assert coeffs["_intercept"] == pytest.approx(e0, rel=1e-3)
        assert coeffs["flops_matmul"] == pytest.approx(e_mm, rel=1e-3)
        assert coeffs["hbm_elementwise"] == pytest.approx(e_ew, rel=1e-3)
        assert coeffs["hbm_data_movement"] == pytest.approx(e_dm, rel=1e-3)

    def test_aggregate_energy_fit_stored_as_tied_class_coeffs(self):
        """Records without breakdowns: the aggregate energy NNLS recovers
        the planted constants and is stored as TIED per-column
        coefficients, so pricing stays one code path."""
        from repro.campaign import fit_hlo_constants
        from repro.engine.decompose import LM_LATENCY_COLUMNS

        e0, e_f, e_b = 0.1, 4e-12, 2e-9
        records = _lm_records(
            lambda fl, ew, dm: 1e-3 + fl / 2e9 + (ew + dm) / 5e8,
            lambda fl, ew, dm: e0 + e_f * fl + e_b * (ew + dm))
        for r in records:
            del r["cost_classes"]
        spec = fit_hlo_constants(records)
        assert spec.meta["energy_fit"] == "aggregate"
        assert spec.meta["energy_mape"] < 1e-6
        tied = spec.class_coeffs["lm_energy"]
        assert tied["_intercept"] == pytest.approx(e0, rel=1e-3)
        for col in LM_LATENCY_COLUMNS:
            want = (e_f if col.startswith("flops_")
                    else 0.0 if col == "collective" else e_b)
            if want:
                assert tied[col] == pytest.approx(want, rel=1e-3), col
        # one pricing path: classwise_seconds over tied coefficients
        # reproduces the aggregate formula on a fresh breakdown
        sums = {"matmul": {"flops": 1e7, "hbm_bytes": 2e6,
                           "collective_bytes": 0.0}}
        priced = float(np.atleast_1d(classwise_seconds(
            ledger_latency_columns([sums]), tied))[0])
        assert priced == pytest.approx(tied["_intercept"] + e_f * 1e7
                                       + e_b * 2e6, rel=1e-3)

    def test_v2_records_skip_energy_fit(self):
        from repro.campaign import fit_hlo_constants

        records = _lm_records(
            lambda fl, ew, dm: 1e-3 + fl / 2e9 + (ew + dm) / 5e8,
            lambda fl, ew, dm: 0.0)   # schema-v2: no energy column
        for r in records:
            del r["energy_j"]
        spec = fit_hlo_constants(records)
        assert spec.meta["energy_fit"] == "none"
        assert "lm_energy" not in spec.class_coeffs


# ---------------------------------------------------------------------------
# zero-compile chain: fitted forest energy → engine → admission
# ---------------------------------------------------------------------------


class _EnergyLMForest:
    """Fitted-forest stand-in with an energy model; no jax anywhere."""

    def __init__(self, gamma_mb=10.0, phi_ms=1.0, energy_j=3.5,
                 energy_fitted=True):
        self.fitted = True
        self.energy_fitted = energy_fitted
        self.meta = {}
        self.gamma_mb, self.phi_ms, self.energy_j = gamma_mb, phi_ms, energy_j
        self.default_device = get_device("host_cpu")

    def content_hash(self):
        return f"fake-energy-{self.energy_j}-{self.energy_fitted}"

    def predict_queries(self, queries):
        n = len(queries)
        return np.full(n, self.gamma_mb), np.full(n, self.phi_ms)

    def predict_energy(self, queries):
        return np.full(len(queries), self.energy_j)


def _q():
    return CostQuery(arch="internlm2-1.8b", bs=2, seq=64, stage="infer",
                     reduced=True)


def test_energy_through_forest_chain_zero_compiles(monkeypatch):
    import jax

    def boom(*a, **k):
        raise AssertionError("energy path invoked the jax compiler")

    monkeypatch.setattr(jax, "jit", boom)
    monkeypatch.setattr(AnalyticalBackend, "_compile_arch", boom)
    engine = CostEngine(EnsembleBackend(
        [ForestBackend(lm=_EnergyLMForest()), AnalyticalBackend()]))
    est = engine.estimate_one(_q())
    assert est.source == "forest" and est.energy_j == 3.5
    ok, info = engine.admit(_q(), energy_budget_j=1.0, safety_margin=0.1)
    assert not ok and info["energy_eff"] == pytest.approx(3.85)
    ok, _ = engine.admit(_q(), energy_budget_j=10.0)
    assert ok


def test_pre_energy_forest_defaults_energy_zero():
    engine = CostEngine(ForestBackend(
        lm=_EnergyLMForest(energy_fitted=False)))
    assert engine.estimate_one(_q()).energy_j == 0.0


def test_cost_estimate_energy_roundtrip_tolerates_old_dicts():
    est = CostEstimate(gamma_mb=1.0, phi_ms=2.0, energy_j=3.0, source="x")
    assert CostEstimate.from_dict(est.to_dict()).energy_j == 3.0
    d = est.to_dict()
    del d["energy_j"]            # pre-energy estimate-cache entry
    assert CostEstimate.from_dict(d).energy_j == 0.0


def test_scheduler_energy_budget_refusal_with_breakdown():
    """energy_budget_j admission: over-envelope compositions refuse with
    the per-class energy breakdown on the refusal info, and dict-valued
    cost_classes buckets don't crash the message formatter."""
    from repro.serve import Decision, Request, SLOScheduler

    class _EnergyEngine:
        def estimate_one(self, query):
            return CostEstimate(
                gamma_mb=10.0, phi_ms=5.0, energy_j=40.0,
                source="analytical",
                detail={"cost_classes": {
                            "matmul": {"flops": 1.0, "hbm_bytes": 2.0,
                                       "collective_bytes": 0.0,
                                       "energy_j": 30.0, "count": 3}},
                        "energy_classes": {"matmul": 30.0,
                                           "elementwise": 10.0}})

    sched = SLOScheduler(
        get_config("internlm2-1.8b", reduced=True), _EnergyEngine(),
        max_len=64, n_slots=4, gamma_budget_mb=1e6, energy_budget_j=20.0)
    req = Request(prompt=np.arange(1, 6, dtype=np.int32), max_new_tokens=4)
    dec, info = sched.admit(req, n_running=0)
    assert dec is Decision.REFUSE and "energy" in info["reason"]
    assert info["energy_eff"] == pytest.approx(44.0)
    err = sched.refusal(req, info)
    assert err.info["energy_classes"]["matmul"] == 30.0
    assert "matmul=" in str(err)   # dict buckets format, not TypeError
    # generous envelope admits
    ok = SLOScheduler(
        get_config("internlm2-1.8b", reduced=True), _EnergyEngine(),
        max_len=64, n_slots=4, gamma_budget_mb=1e6, energy_budget_j=100.0)
    dec, info = ok.admit(req, n_running=0)
    assert dec is Decision.ADMIT and info["energy_j"] == 40.0


# ---------------------------------------------------------------------------
# DeviceSpec power envelope: modes, fingerprint, persistence
# ---------------------------------------------------------------------------


class TestPowerEnvelope:
    def test_with_power_mode_applies_and_refingerprints(self):
        tx2 = get_device("tx2_like")
        maxq = tx2.with_power_mode("MAXQ")
        assert maxq.name == "tx2_like@MAXQ"
        assert maxq.peak_w == 7.5
        # a mode legitimately moves the roofline denominators too
        assert maxq.peak_flops == pytest.approx(0.67e12)
        assert maxq.fingerprint() != tx2.fingerprint()
        assert maxq.dynamic_w == pytest.approx(7.5 - 1.4)
        with pytest.raises(KeyError, match="MAXG"):
            tx2.with_power_mode("MAXG")

    def test_persistence_roundtrip_keeps_power_fields(self, tmp_path):
        tx2 = get_device("tx2_like")
        for ext in ("json", "npz"):
            p = str(tmp_path / f"dev.{ext}")
            save_device_spec(p, tx2)
            back = load_device_spec(p)
            assert back.idle_w == tx2.idle_w
            assert back.peak_w == tx2.peak_w
            assert back.power_modes == tx2.power_modes
            assert back.fingerprint() == tx2.fingerprint(), ext

    def test_envelope_validation(self):
        with pytest.raises(ValueError, match="negative power"):
            DeviceSpec(name="bad", peak_flops=1.0, hbm_bw=1.0, idle_w=-1.0)
        with pytest.raises(ValueError, match="non-mode fields"):
            DeviceSpec(name="bad", peak_flops=1.0, hbm_bw=1.0,
                       power_modes={"X": {"hbm_bytes": 1.0}})
