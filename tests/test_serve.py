"""Serving-path tests: admission edge cases, on-device sampling, ragged
prompts, EOS trimming, the continuous-batching engine + paged KV cache,
and the zero-compile SLO scheduler (ISSUE 6)."""

import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.engine import (
    BackendUnavailable,
    CostEngine,
    CostEstimate,
    ForestBackend,
    get_device,
)
from repro.kernels.autotune import KernelTuner
from repro.models import transformer as T
from repro.serve import (
    ContinuousConfig,
    ContinuousEngine,
    Decision,
    PagedKVCache,
    PlacementRefused,
    Request,
    RequestState,
    ServeConfig,
    ServeEngine,
    SLOScheduler,
    pad_ragged,
    resolve_block_size,
)


def _cfg():
    return get_config("internlm2-1.8b", reduced=True)


@pytest.fixture(scope="module")
def model():
    cfg = _cfg()
    return cfg, T.init_params(cfg, 0)


def _prompts(lens=(5, 9, 13), seed=0, vocab=128):
    rng = np.random.default_rng(seed)
    return [rng.integers(2, vocab, (n,)).astype(np.int32) for n in lens]


# ---------------------------------------------------------------------------
# admission edge cases (legacy engine)
# ---------------------------------------------------------------------------


class _StubCostEngine:
    def __init__(self, ok=True, gamma_mb=100.0):
        self.ok, self.gamma_mb = ok, gamma_mb
        self.queries, self.budgets = [], []

    def admit(self, query, *, gamma_budget_mb=None, phi_budget_ms=None,
              safety_margin=0.1):
        self.queries.append(query)
        self.budgets.append(gamma_budget_mb)
        return self.ok, {"gamma_mb": self.gamma_mb, "phi_ms": 1.0,
                         "gamma_eff": self.gamma_mb * (1 + safety_margin),
                         "phi_eff": 1.1, "source": "stub"}


class _UnavailableCostEngine:
    def admit(self, query, **kw):
        raise BackendUnavailable("no backend can score this arch")

    def estimate_one(self, query):
        raise BackendUnavailable("no backend can score this arch")


def test_external_engine_without_device_keeps_budget_none(model):
    """gamma_budget_mb=None + external cost_engine + no device: the gate
    still runs, but with an unbounded budget (nothing to cap against)."""
    cfg, params = model
    gate = _StubCostEngine(ok=True)
    eng = ServeEngine(cfg, params, ServeConfig(max_len=64, n_slots=2),
                      cost_engine=gate)
    assert gate.budgets == [None]
    assert eng.admission_info["source"] == "stub"


def test_backend_unavailable_skips_gate(model):
    cfg, params = model
    eng = ServeEngine(cfg, params, ServeConfig(max_len=64, n_slots=2),
                      cost_engine=_UnavailableCostEngine())
    assert "no backend can score" in eng.admission_info["skipped"]


def test_placement_refused_message_and_info(model):
    cfg, params = model
    with pytest.raises(PlacementRefused) as ei:
        ServeEngine(cfg, params,
                    ServeConfig(max_len=64, n_slots=2, gamma_budget_mb=1.0),
                    cost_engine=_StubCostEngine(ok=False))
    msg = str(ei.value)
    assert "internlm2-1.8b-smoke" in msg and "n_slots=2" in msg
    assert "110MB effective" in msg            # gamma_eff = 100 * 1.1
    assert ei.value.info["source"] == "stub"   # evidence travels on .info


# ---------------------------------------------------------------------------
# on-device sampling (seeded-reproducibility contract, both paths)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("temperature", [0.0, 0.8])
def test_sampling_deterministic_under_fixed_seed(model, temperature):
    cfg, params = model
    prompts = np.random.default_rng(1).integers(
        1, cfg.vocab, (2, 8)).astype(np.int32)

    def gen(seed):
        scfg = ServeConfig(max_len=64, n_slots=2, temperature=temperature,
                           seed=seed)
        return ServeEngine(cfg, params, scfg).generate(
            prompts, max_new_tokens=6)

    np.testing.assert_array_equal(gen(3)["tokens"], gen(3)["tokens"])
    if temperature > 0:
        assert not np.array_equal(gen(3)["tokens"], gen(4)["tokens"])


# ---------------------------------------------------------------------------
# ragged prompts + EOS trimming (legacy engine)
# ---------------------------------------------------------------------------


def test_pad_ragged_left_pads():
    tokens, lens = pad_ragged([np.array([7, 8]), np.array([1, 2, 3, 4])])
    np.testing.assert_array_equal(lens, [2, 4])
    np.testing.assert_array_equal(tokens[0], [0, 0, 7, 8])
    np.testing.assert_array_equal(tokens[1], [1, 2, 3, 4])


def test_ragged_generate_matches_solo_rows(model):
    """Each row of a mixed-length batch must decode exactly what it would
    decode alone — the garbage-position bug ragged support fixes."""
    cfg, params = model
    prompts = _prompts()
    eng = ServeEngine(cfg, params, ServeConfig(max_len=64, n_slots=3,
                                               eos_id=0))
    out = eng.generate(prompts, max_new_tokens=6)
    np.testing.assert_array_equal(out["prompt_lens"], [5, 9, 13])
    for i, p in enumerate(prompts):
        solo = ServeEngine(cfg, params, ServeConfig(
            max_len=64, n_slots=3, eos_id=0)).generate(
                p[None, :], max_new_tokens=6)
        n = min(solo["tokens"].shape[1], out["tokens"].shape[1])
        np.testing.assert_array_equal(out["tokens"][i, :n],
                                      solo["tokens"][0, :n])


def test_eos_trimmed_outputs_and_counts(model):
    cfg, params = model
    prompt = _prompts(lens=(6,))[0]
    ref = ServeEngine(cfg, params, ServeConfig(
        max_len=64, n_slots=1, eos_id=0)).generate(
            prompt[None, :], max_new_tokens=6)
    # re-generate with eos = the 3rd greedy token: trim must cut there
    eos = int(ref["tokens"][0, 2])
    out = ServeEngine(cfg, params, ServeConfig(
        max_len=64, n_slots=1, eos_id=eos)).generate(
            prompt[None, :], max_new_tokens=6)
    assert out["token_counts"][0] == 2
    np.testing.assert_array_equal(out["outputs"][0], ref["tokens"][0, :2])
    assert out["finished"][0]


def test_request_output_trims_at_first_eos():
    req = Request(prompt=np.array([5], np.int32))
    req.tokens = [3, 9, 7, 9, 4]
    np.testing.assert_array_equal(req.output(eos_id=7), [3, 9])
    np.testing.assert_array_equal(req.output(eos_id=1), [3, 9, 7, 9, 4])


# ---------------------------------------------------------------------------
# paged KV cache + serve_kv tiling through the TuningCache
# ---------------------------------------------------------------------------


def test_serve_kv_block_size_resolved_through_tuning_cache(tmp_path):
    cfg = _cfg()
    path = str(tmp_path / "tuning.json")
    t1 = KernelTuner(cache=path)
    b1 = resolve_block_size(cfg, n_slots=4, max_len=128, tuner=t1)
    assert b1 >= 1 and (t1.hits, t1.misses) == (0, 1)
    assert resolve_block_size(cfg, n_slots=4, max_len=128, tuner=t1) == b1
    assert (t1.hits, t1.misses) == (1, 1)      # in-process memo hit
    t2 = KernelTuner(cache=path)               # fresh tuner, same disk cache
    assert resolve_block_size(cfg, n_slots=4, max_len=128, tuner=t2) == b1
    assert (t2.hits, t2.misses) == (1, 0)      # on-disk TuningCache hit
    # device-fingerprint-keyed: another device's entry never aliases
    t3 = KernelTuner(device=get_device("tx2_like"), cache=path)
    resolve_block_size(cfg, n_slots=4, max_len=128, tuner=t3)
    assert t3.misses == 1


def test_paged_pool_allocator_and_footprint():
    cfg = _cfg()
    kv = PagedKVCache(cfg, n_slots=8, max_len=512, block_size=64)
    assert kv.bytes < kv.dense_bytes           # the point of paging
    free0 = kv.n_free_blocks
    a = kv.alloc(kv.blocks_for(100))
    assert len(a) == 2 and 0 not in a          # block 0 is reserved scratch
    assert kv.alloc(free0) is None             # over-ask: nothing allocated
    assert kv.n_free_blocks == free0 - 2
    kv.free(a)
    assert kv.n_free_blocks == free0


# ---------------------------------------------------------------------------
# continuous engine
# ---------------------------------------------------------------------------


def test_continuous_matches_lockstep_greedy(model):
    """Strongest correctness check: the paged, ragged, slot-indexed decode
    must reproduce the legacy engine's greedy tokens per request."""
    cfg, params = model
    prompts = _prompts()
    legacy = ServeEngine(cfg, params, ServeConfig(
        max_len=64, n_slots=3, eos_id=0)).generate(prompts, max_new_tokens=8)
    ce = ContinuousEngine(cfg, params, ContinuousConfig(
        max_len=64, n_slots=3, eos_id=0, block_size=16))
    reqs = [Request(prompt=p, max_new_tokens=8) for p in prompts]
    ce.run(reqs)
    for i, r in enumerate(reqs):
        assert r.state is RequestState.FINISHED
        np.testing.assert_array_equal(
            r.tokens, legacy["tokens"][i, : len(r.tokens)])


def test_continuous_slot_reuse_and_pool_reclaim(model):
    """More requests than slots, mixed token budgets, a pool smaller than
    n_slots × max_len: slots and blocks must recycle until the queue
    drains, and every block must return to the free list."""
    cfg, params = model
    rng = np.random.default_rng(2)
    reqs = [Request(prompt=rng.integers(2, 128, (l,)).astype(np.int32),
                    max_new_tokens=m)
            for l, m in [(4, 3), (7, 10), (3, 5), (11, 2), (6, 8), (5, 4)]]
    ce = ContinuousEngine(cfg, params, ContinuousConfig(
        max_len=64, n_slots=2, eos_id=0, block_size=16, pool_tokens=64))
    done = ce.run(reqs)
    assert len(done) == len(reqs)
    assert all(r.n_generated <= r.max_new_tokens for r in reqs)
    assert all(r.ttft_s is not None and r.ttft_s >= 0 for r in reqs)
    assert ce.kv.n_free_blocks == ce.kv.n_blocks - 1


def test_continuous_temperature_seeded(model):
    cfg, params = model
    prompt = _prompts(lens=(6,))[0]

    def gen(seed):
        ce = ContinuousEngine(cfg, params, ContinuousConfig(
            max_len=64, n_slots=2, eos_id=0, block_size=16,
            temperature=0.8, seed=seed))
        req = Request(prompt=prompt, max_new_tokens=6)
        ce.run([req])
        return req.tokens

    assert gen(7) == gen(7)


# ---------------------------------------------------------------------------
# SLO scheduler: cost-model-driven decisions, zero compiles
# ---------------------------------------------------------------------------


class _FakeLMForest:
    """Fitted-forest stand-in: constant (Γ, Φ) per query, no jax anywhere."""

    fitted = True
    meta: dict = {}

    def __init__(self, gamma_mb, phi_ms=1.0):
        self.gamma_mb, self.phi_ms = gamma_mb, phi_ms
        self.default_device = get_device("host_cpu")

    def content_hash(self):
        return f"fake-{self.gamma_mb}-{self.phi_ms}"

    def predict_queries(self, queries):
        n = len(queries)
        return (np.full(n, self.gamma_mb), np.full(n, self.phi_ms))


def _scheduler(gamma_mb, budget_mb, phi_ms=1.0, **kw):
    engine = CostEngine(ForestBackend(lm=_FakeLMForest(gamma_mb, phi_ms)))
    return SLOScheduler(_cfg(), engine, max_len=64, n_slots=4,
                        gamma_budget_mb=budget_mb, **kw)


def test_scheduler_cost_driven_zero_compiles(monkeypatch):
    """Over-budget composition refused, fitting one admitted — and the
    whole decision path triggers zero JAX compilations (forest chain)."""
    import jax

    from repro.engine import AnalyticalBackend

    def boom(*a, **k):
        raise AssertionError("admission path invoked the jax compiler")

    monkeypatch.setattr(jax, "jit", boom)
    monkeypatch.setattr(AnalyticalBackend, "_compile_arch", boom)

    req = Request(prompt=np.arange(1, 9, dtype=np.int32), max_new_tokens=8)
    dec, info = _scheduler(gamma_mb=500.0, budget_mb=100.0).admit(
        req, n_running=1)
    assert dec is Decision.REFUSE
    assert "budget" in info["reason"] and info["bs"] == 2
    assert info["source"] == "forest"

    dec, info = _scheduler(gamma_mb=50.0, budget_mb=100.0).admit(
        req, n_running=1)
    assert dec is Decision.ADMIT and info["gamma_eff"] == pytest.approx(55.0)


def test_scheduler_refusal_carries_ledger_breakdown():
    class _BreakdownEngine:
        def estimate_one(self, query):
            return CostEstimate(
                gamma_mb=900.0, phi_ms=5.0, source="analytical",
                detail={"cost_classes": {"matmul": 700.0, "elementwise": 150.0,
                                         "collective": 50.0}})

    sched = SLOScheduler(_cfg(), _BreakdownEngine(), max_len=64, n_slots=4,
                         gamma_budget_mb=100.0)
    req = Request(prompt=np.arange(1, 6, dtype=np.int32), max_new_tokens=4)
    dec, info = sched.admit(req, n_running=0)
    assert dec is Decision.REFUSE
    err = sched.refusal(req, info)
    assert isinstance(err, PlacementRefused)
    assert err.info["cost_classes"]["matmul"] == 700.0
    assert "matmul=700" in str(err)            # breakdown in the message


def test_scheduler_slo_and_window_refusals():
    req_big = Request(prompt=np.arange(1, 60, dtype=np.int32),
                      max_new_tokens=32)
    dec, info = _scheduler(10.0, 1e6).admit(req_big, n_running=0)
    assert dec is Decision.REFUSE and "max_len" in info["reason"]

    # per-request SLO: phi 640ms over a 64-token window → 10ms/token proxy
    req = Request(prompt=np.arange(1, 9, dtype=np.int32), max_new_tokens=8,
                  slo_ms=1.0)
    dec, info = _scheduler(10.0, 1e6, phi_ms=640.0).admit(req, n_running=0)
    assert dec is Decision.REFUSE and "SLO" in info["reason"]
    req.slo_ms = 100.0
    dec, _ = _scheduler(10.0, 1e6, phi_ms=640.0).admit(req, n_running=0)
    assert dec is Decision.ADMIT


def test_scheduler_backend_unavailable_admits_ungated():
    sched = SLOScheduler(_cfg(), _UnavailableCostEngine(), max_len=64,
                         n_slots=4, gamma_budget_mb=1.0)
    req = Request(prompt=np.arange(1, 9, dtype=np.int32), max_new_tokens=4)
    dec, info = sched.admit(req, n_running=0)
    assert dec is Decision.ADMIT and "skipped" in info


def test_continuous_engine_refuses_via_scheduler(model):
    cfg, params = model
    engine = CostEngine(ForestBackend(lm=_FakeLMForest(5000.0)))
    ce = ContinuousEngine(cfg, params, ContinuousConfig(
        max_len=64, n_slots=2, eos_id=0, block_size=16,
        gamma_budget_mb=100.0), cost_engine=engine)
    req = Request(prompt=np.arange(1, 9, dtype=np.int32), max_new_tokens=4)
    ce.run([req])
    assert req.state is RequestState.REFUSED
    assert isinstance(req.refusal, PlacementRefused)
    assert ce.metrics()["refused"] == 1 and ce.metrics()["finished"] == 0


# ---------------------------------------------------------------------------
# admission decision bugfixes (ISSUE 7): DEFER, TTFT, oversized prompts
# ---------------------------------------------------------------------------


class _BsFakeLMForest(_FakeLMForest):
    """Batch-sensitive stand-in: Γ grows linearly with the priced bs, so
    a composition can be over budget at bs=2 yet fit alone at bs=1."""

    def content_hash(self):
        return f"bsfake-{self.gamma_mb}-{self.phi_ms}"

    def predict_queries(self, queries):
        g = np.array([self.gamma_mb * q.bs for q in queries])
        return g, np.full(len(queries), self.phi_ms)


def _bs_scheduler(gamma_per_slot, budget_mb, **kw):
    engine = CostEngine(ForestBackend(lm=_BsFakeLMForest(gamma_per_slot)))
    return SLOScheduler(_cfg(), engine, max_len=64, n_slots=4,
                        gamma_budget_mb=budget_mb, **kw)


def test_scheduler_defers_occupancy_transient_misses():
    """An over-budget composition that fits alone at bs=1 is DEFERred
    (retry as slots drain), not refused for good; one that cannot fit
    even alone is still REFUSE.  Pre-fix the DEFER branch was dead: the
    scheduler returned only ADMIT/REFUSE."""
    req = Request(prompt=np.arange(1, 9, dtype=np.int32), max_new_tokens=8)

    # 60MB/slot, 100MB budget: bs=2 → 132MB eff (miss), bs=1 → 66MB (fits)
    dec, info = _bs_scheduler(60.0, 100.0).admit(req, n_running=1)
    assert dec is Decision.DEFER
    assert "defer" in info and "bs=1" in info["defer"]
    assert "budget" in info["reason"]           # the transient miss, kept

    # same request with the slot free → straight ADMIT
    dec, _ = _bs_scheduler(60.0, 100.0).admit(req, n_running=0)
    assert dec is Decision.ADMIT

    # 120MB/slot: over budget even alone → REFUSE, occupancy irrelevant
    dec, info = _bs_scheduler(120.0, 100.0).admit(req, n_running=1)
    assert dec is Decision.REFUSE and "defer" not in info


def test_continuous_engine_defer_retries_and_finishes(model):
    """End to end: the second arrival DEFERs while the first occupies its
    slot, stays queued (not refused), and is admitted once the first
    drains — both finish."""
    cfg, params = model
    engine = CostEngine(ForestBackend(lm=_BsFakeLMForest(60.0)))
    ce = ContinuousEngine(cfg, params, ContinuousConfig(
        max_len=64, n_slots=2, eos_id=0, block_size=16,
        gamma_budget_mb=100.0), cost_engine=engine)
    a = Request(prompt=np.arange(1, 6, dtype=np.int32), max_new_tokens=2)
    b = Request(prompt=np.arange(1, 6, dtype=np.int32), max_new_tokens=2)
    ce.run([a, b])
    assert ce.metrics()["refused"] == 0
    assert ce.metrics()["finished"] == 2
    assert a.state is RequestState.FINISHED
    assert b.state is RequestState.FINISHED


def test_scheduler_ttft_slo_refusal():
    """ServeSLO.ttft_ms is actually enforced now: the request's own
    prefill (priced at bs=1 over its prompt) over the target → REFUSE.
    Pre-fix the field was stored but never read."""
    from repro.serve import ServeSLO

    req = Request(prompt=np.arange(1, 9, dtype=np.int32), max_new_tokens=8)
    dec, info = _scheduler(10.0, 1e6, phi_ms=100.0,
                           slo=ServeSLO(ttft_ms=50.0)).admit(
        req, n_running=0)
    assert dec is Decision.REFUSE and "TTFT" in info["reason"]
    assert info["ttft_proxy_ms"] == pytest.approx(110.0)

    dec, _ = _scheduler(10.0, 1e6, phi_ms=100.0,
                        slo=ServeSLO(ttft_ms=200.0)).admit(req, n_running=0)
    assert dec is Decision.ADMIT


def test_ungated_engine_refuses_oversized_prompt(model):
    """cost_engine=None: an oversized prompt must be REFUSED cleanly by
    the engine's own context-window check.  Pre-fix this crashed in
    ``_prefill_into`` (width − prompt_len goes negative)."""
    cfg, params = model
    ce = ContinuousEngine(cfg, params, ContinuousConfig(
        max_len=32, n_slots=2, eos_id=0, block_size=16))
    big = Request(prompt=np.arange(1, 41, dtype=np.int32), max_new_tokens=4)
    ok = Request(prompt=np.arange(1, 6, dtype=np.int32), max_new_tokens=2)
    ce.run([big, ok])
    assert big.state is RequestState.REFUSED
    assert isinstance(big.refusal, PlacementRefused)
    assert "max_len" in str(big.refusal)
    # the engine stays healthy: the normal request still completes
    assert ok.state is RequestState.FINISHED
    m = ce.metrics()
    assert m["refused"] == 1 and m["finished"] == 1


def test_gated_engine_refuses_oversized_prompt_before_scheduler(model):
    """With a scheduler attached the window check fires in the engine
    first — the cost model is never consulted for a request that cannot
    fit regardless of price."""
    cfg, params = model

    class _CountingEngine:
        calls = 0

        def estimate_one(self, query):
            type(self).calls += 1
            return CostEstimate(gamma_mb=1.0, phi_ms=1.0, source="stub")

    ce = ContinuousEngine(cfg, params, ContinuousConfig(
        max_len=32, n_slots=2, eos_id=0, block_size=16,
        gamma_budget_mb=1e6), cost_engine=_CountingEngine())
    big = Request(prompt=np.arange(1, 41, dtype=np.int32), max_new_tokens=4)
    ce.run([big])
    assert big.state is RequestState.REFUSED
    assert _CountingEngine.calls == 0


# ---------------------------------------------------------------------------
# per-request query helper
# ---------------------------------------------------------------------------


def test_estimate_requests_buckets_ragged_lens():
    class _CountingBackend:
        name = "counting"

        def __init__(self):
            self.batches = []

        def estimate(self, queries):
            self.batches.append(queries)
            return [CostEstimate(gamma_mb=float(q.seq), phi_ms=1.0,
                                 source=self.name) for q in queries]

    backend = _CountingBackend()
    engine = CostEngine(backend)
    ests = engine.estimate_requests("internlm2-1.8b", [3, 60, 70, 5],
                                    bucket=64)
    # 4 ragged lengths collapse onto 2 bucketed queries in one batch
    assert len(backend.batches) == 1 and len(backend.batches[0]) == 2
    assert [e.gamma_mb for e in ests] == [64.0, 64.0, 128.0, 64.0]
