"""DeviceSpec registry + property tests: fingerprint sensitivity to every
fitted constant, serialization round-trips, roofline monotonicity."""

import dataclasses
import json
import os

import pytest

from repro.core.features import ConvLayerSpec, NetworkSpec
from repro.engine import (
    AnalyticalBackend,
    CostEngine,
    CostQuery,
    DeviceSpec,
    from_jax_device,
    get_device,
    list_devices,
    load_device_spec,
    register_device,
    resolve_device,
    save_device_spec,
)
from repro.engine.devices import FITTED_FIELDS
from tests._hypothesis import given, settings, st

NET = NetworkSpec("probe", (
    ConvLayerSpec(n=8, m=3, k=3, stride=1, padding=1, ip=16),
    ConvLayerSpec(n=16, m=8, k=3, stride=2, padding=1, ip=16),
))


def _phi(device: DeviceSpec, bs: int = 8) -> float:
    backend = AnalyticalBackend(device=device)
    return backend.estimate([CostQuery(spec=NET, bs=bs)])[0].phi_ms


# -- registry -----------------------------------------------------------------


def test_builtin_registry():
    for name in ("host_cpu", "tx2_like", "tpu_v5e"):
        assert name in list_devices()
        spec = get_device(name)
        assert spec.name == name and not spec.calibrated
    # host_cpu carries the pre-registry HOST_CPU constants
    hc = get_device("host_cpu")
    assert (hc.peak_flops, hc.hbm_bw) == (5e10, 2e10)


def test_get_device_unknown_names_registered():
    with pytest.raises(KeyError, match="host_cpu"):
        get_device("nope")


def test_register_device_no_silent_overwrite():
    spec = DeviceSpec(name="test_dev_reg", peak_flops=1e12, hbm_bw=1e11)
    register_device(spec)
    with pytest.raises(ValueError):
        register_device(spec)
    assert register_device(spec, overwrite=True) is spec


def test_resolve_device_forms(tmp_path):
    assert resolve_device(None).name == "host_cpu"
    assert resolve_device("tx2_like").name == "tx2_like"
    spec = DeviceSpec(name="inline", peak_flops=1e12, hbm_bw=1e11)
    assert resolve_device(spec) is spec
    legacy = resolve_device({"peak_flops_bf16": 2e12, "hbm_bw": 3e11})
    assert legacy.peak_flops == 2e12 and legacy.hbm_bw == 3e11
    path = str(tmp_path / "dev.json")
    save_device_spec(path, spec)
    assert resolve_device(path).fingerprint() == spec.fingerprint()
    with pytest.raises(TypeError):
        resolve_device(42)


def test_from_jax_device_registers_uncalibrated_spec():
    spec = from_jax_device()
    assert spec.name.startswith("jax_") and not spec.calibrated
    assert spec.name in list_devices()
    assert spec.peak_flops > 0 and spec.hbm_bytes > 0


def test_validation():
    with pytest.raises(ValueError):
        DeviceSpec(name="bad", peak_flops=0.0, hbm_bw=1e9)
    with pytest.raises(ValueError):
        DeviceSpec(name="bad", peak_flops=1e9, hbm_bw=1e9, combine="mean")
    with pytest.raises(ValueError):
        DeviceSpec(name="bad", peak_flops=1e9, hbm_bw=1e9, alloc_granularity=0)


# -- fingerprint sensitivity --------------------------------------------------


def _bumped(spec: DeviceSpec, field: str) -> DeviceSpec:
    v = getattr(spec, field)
    if field == "combine":
        return dataclasses.replace(spec, combine="sum" if v == "max" else "max")
    if field == "calibrated":
        return dataclasses.replace(spec, calibrated=not v)
    if field == "alloc_granularity":
        return dataclasses.replace(spec, alloc_granularity=int(v) + 1)
    if field == "class_coeffs":
        bumped = dict(v)
        bumped["cnn_latency"] = {"_intercept": bumped.get(
            "cnn_latency", {}).get("_intercept", 0.0) + 1e-3}
        return dataclasses.replace(spec, class_coeffs=bumped)
    if field == "power_modes":
        bumped = dict(v)
        bumped["_BUMP"] = {"peak_w": spec.peak_w + 1.0}
        return dataclasses.replace(spec, power_modes=bumped)
    return dataclasses.replace(spec, **{field: v * 1.5 + 1e-6})


def test_fingerprint_sensitive_to_every_fitted_constant():
    base = get_device("tx2_like")
    for field in FITTED_FIELDS:
        assert _bumped(base, field).fingerprint() != base.fingerprint(), field
    # name and meta are NOT prediction-relevant: same constants, same key
    assert dataclasses.replace(base, name="alias").fingerprint() == base.fingerprint()


def test_spec_stays_hashable_with_class_coeffs():
    # frozen specs are used as set members / dict keys; the class_coeffs
    # dict must not break the generated __hash__ (eq still covers it)
    spec = _bumped(get_device("host_cpu"), "class_coeffs")
    assert spec in {spec}
    assert spec != get_device("host_cpu")


def test_analytical_cache_salt_tracks_device_fingerprint():
    base = AnalyticalBackend(device="host_cpu")
    for field in FITTED_FIELDS:
        bumped = AnalyticalBackend(device=_bumped(get_device("host_cpu"), field))
        assert bumped.cache_salt() != base.cache_salt(), field


def test_engine_level_device_salts_keys():
    backend = AnalyticalBackend()
    e1 = CostEngine(backend, device="host_cpu")
    e2 = CostEngine(backend, device="tx2_like")
    assert e1._salt() != e2._salt()


# -- serialization ------------------------------------------------------------


@pytest.mark.parametrize("ext", ["json", "npz"])
def test_save_load_roundtrip(tmp_path, ext):
    spec = DeviceSpec(
        name="fitted", peak_flops=1.23e12, hbm_bw=4.56e10, ici_bw=7e9,
        hbm_bytes=8e9, launch_overhead_s=2.5e-3, alloc_granularity=512,
        mem_weight_scale=4.1, mem_act_scale=1.7, mem_base_mb=0.4,
        combine="sum", calibrated=True, meta={"phi_mape": 0.12})
    path = str(tmp_path / f"spec.{ext}")
    save_device_spec(path, spec)
    loaded = load_device_spec(path)
    assert loaded == spec
    assert loaded.fingerprint() == spec.fingerprint()
    assert loaded.meta["phi_mape"] == 0.12
    # predictions are identical through the backend
    a = AnalyticalBackend(device=spec).estimate([CostQuery(spec=NET, bs=4)])[0]
    b = AnalyticalBackend(device=loaded).estimate([CostQuery(spec=NET, bs=4)])[0]
    assert (a.gamma_mb, a.phi_ms) == (b.gamma_mb, b.phi_ms)


def test_json_spec_file_is_plain_json(tmp_path):
    path = str(tmp_path / "spec.json")
    save_device_spec(path, get_device("tx2_like"))
    with open(path) as f:
        d = json.load(f)
    assert d["name"] == "tx2_like"
    assert os.path.getsize(path) > 0


# -- property tests (hypothesis; skip cleanly without it) ---------------------

spec_strategy = st.builds(
    DeviceSpec,
    name=st.just("prop"),
    peak_flops=st.floats(1e9, 1e15),
    hbm_bw=st.floats(1e8, 1e13),
    ici_bw=st.floats(1e7, 1e12),
    hbm_bytes=st.floats(1e8, 1e12),
    launch_overhead_s=st.floats(0, 1e-2),
    alloc_granularity=st.integers(1, 4096),
    mem_weight_scale=st.floats(0, 10),
    mem_act_scale=st.floats(0, 10),
    mem_base_mb=st.floats(0, 100),
    combine=st.sampled_from(["max", "sum"]),
    calibrated=st.booleans(),
)


@given(spec=spec_strategy)
@settings(max_examples=40, deadline=None)
def test_prop_dict_roundtrip(spec):
    again = DeviceSpec.from_dict(json.loads(json.dumps(spec.to_dict())))
    assert again == spec
    assert again.fingerprint() == spec.fingerprint()


@given(spec=spec_strategy, factor=st.floats(1.0, 1e3))
@settings(max_examples=40, deadline=None)
def test_prop_more_flops_never_slower(spec, factor):
    faster = dataclasses.replace(spec, peak_flops=spec.peak_flops * factor)
    assert _phi(faster) <= _phi(spec)


@given(spec=spec_strategy, factor=st.floats(1.0, 1e3))
@settings(max_examples=40, deadline=None)
def test_prop_more_bandwidth_never_slower(spec, factor):
    faster = dataclasses.replace(spec, hbm_bw=spec.hbm_bw * factor)
    assert _phi(faster) <= _phi(spec)


@given(spec=spec_strategy, field=st.sampled_from(list(FITTED_FIELDS)))
@settings(max_examples=60, deadline=None)
def test_prop_fingerprint_sensitive(spec, field):
    assert _bumped(spec, field).fingerprint() != spec.fingerprint()
