"""Suite-wide isolation: the kernel autotuner's implicit lookups (model
tracing, ops wrappers) must never write to the user-level tuning cache
(~/.cache/repro) from tests.  Redirect the default cache file to a
per-session scratch path before any tuner is created."""

import os
import tempfile

os.environ.setdefault(
    "REPRO_TUNING_CACHE",
    os.path.join(tempfile.mkdtemp(prefix="repro-test-tuning-"),
                 "kernel_tuning.json"),
)
