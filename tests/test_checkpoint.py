"""Checkpointing: atomicity, keep-N GC, bf16 roundtrip, exact resume."""

import os
import shutil

import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ckpt


def _state(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {
            "w": rng.standard_normal((4, 8)).astype(np.float32),
            "b": rng.standard_normal((8,)).astype(jnp.bfloat16),
        },
        "opt": {"step": np.int32(7), "m": {"w": rng.standard_normal((4, 8)).astype(np.float32)}},
    }


def test_roundtrip_with_bf16(tmp_path):
    d = str(tmp_path / "ck")
    s = _state()
    ckpt.save_checkpoint(d, 10, s)
    step, restored = ckpt.restore_checkpoint(d, template=s)
    assert step == 10
    np.testing.assert_array_equal(restored["params"]["w"], s["params"]["w"])
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["b"], dtype=np.float32),
        np.asarray(s["params"]["b"], dtype=np.float32),
    )
    assert restored["params"]["b"].dtype == jnp.bfloat16


def test_keep_n_gc(tmp_path):
    d = str(tmp_path / "ck")
    s = _state()
    for step in range(5):
        ckpt.save_checkpoint(d, step, s, keep=2)
    assert ckpt.list_steps(d) == [3, 4]


def test_latest_ignores_partial_tmp(tmp_path):
    d = str(tmp_path / "ck")
    s = _state()
    ckpt.save_checkpoint(d, 1, s)
    # simulate a crash mid-write: tmp dir without manifest
    os.makedirs(os.path.join(d, "step_000000002.tmp"))
    # and a committed-looking dir without manifest (unreadable)
    os.makedirs(os.path.join(d, "step_000000003"))
    assert ckpt.latest_step(d) == 1


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        ckpt.restore_checkpoint(str(tmp_path / "none"))


def test_restore_specific_step(tmp_path):
    d = str(tmp_path / "ck")
    s1, s2 = _state(1), _state(2)
    ckpt.save_checkpoint(d, 1, s1, keep=5)
    ckpt.save_checkpoint(d, 2, s2, keep=5)
    _, r1 = ckpt.restore_checkpoint(d, step=1, template=s1)
    np.testing.assert_array_equal(r1["params"]["w"], s1["params"]["w"])
