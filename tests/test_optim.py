"""Optimizer + gradient-compression tests."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.compression import compress_grads, compression_stats, init_error_state
from repro.optim.optimizer import (
    OptimizerConfig,
    apply_updates,
    clip_by_global_norm,
    cosine_schedule,
    global_norm,
    init_opt_state,
)


def test_adamw_first_step_matches_manual():
    cfg = OptimizerConfig(kind="adamw", lr=0.1, weight_decay=0.0,
                          clip_norm=None, warmup_steps=0, total_steps=10**9)
    p = {"w": jnp.ones((3,))}
    g = {"w": jnp.full((3,), 0.5)}
    st = init_opt_state(p, cfg)
    new_p, new_st, _ = apply_updates(p, g, st, cfg)
    # bias-corrected first AdamW step ≈ lr · g/|g| = lr (sign-like)
    lr0 = cosine_schedule(cfg, jnp.int32(1))
    expect = 1.0 - lr0 * (0.5 / (0.5 + cfg.eps))
    np.testing.assert_allclose(new_p["w"], expect, rtol=1e-5)
    assert int(new_st["step"]) == 1


def test_sgdm_accumulates_momentum():
    cfg = OptimizerConfig(kind="sgdm", lr=1.0, momentum=0.5, clip_norm=None,
                          warmup_steps=0, total_steps=10**9)
    p = {"w": jnp.zeros((2,))}
    st = init_opt_state(p, cfg)
    g = {"w": jnp.ones((2,))}
    p, st, _ = apply_updates(p, g, st, cfg)
    p, st, _ = apply_updates(p, g, st, cfg)
    np.testing.assert_allclose(st["m"]["w"], 1.5)  # 0.5·1 + 1


def test_warmup_and_cosine():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=110)
    assert float(cosine_schedule(cfg, jnp.int32(5))) < 1.0
    np.testing.assert_allclose(float(cosine_schedule(cfg, jnp.int32(10))), 1.0,
                               rtol=1e-5)
    assert float(cosine_schedule(cfg, jnp.int32(110))) < 1e-6


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 3.0)}  # norm 6
    clipped, gn = clip_by_global_norm(g, 1.5)
    np.testing.assert_allclose(float(gn), 6.0, rtol=1e-6)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.5, rtol=1e-5)


def test_adamw_converges_on_quadratic():
    cfg = OptimizerConfig(kind="adamw", lr=0.1, weight_decay=0.0,
                          warmup_steps=0, total_steps=10**9, clip_norm=1.0)
    target = jnp.asarray(np.random.default_rng(0).standard_normal(8))
    p = {"w": jnp.zeros(8)}
    st = init_opt_state(p, cfg)
    for _ in range(300):
        g = {"w": p["w"] - target}
        p, st, _ = apply_updates(p, g, st, cfg)
    assert float(jnp.abs(p["w"] - target).max()) < 0.05


def test_topk_compression_with_error_feedback_converges():
    # stability: released error bursts are ~(1/ratio)·g, so lr·(1/ratio) < 1
    target = jnp.asarray(np.random.default_rng(1).standard_normal(64))
    p = {"w": jnp.zeros(64)}
    err = init_error_state(p)
    lr, ratio = 0.05, 0.1
    for _ in range(600):
        g = {"w": p["w"] - target}
        sent, err = compress_grads(g, err, ratio=ratio)
        p = jax.tree.map(lambda w, s: w - lr * s, p, sent)
    assert float(jnp.abs(p["w"] - target).max()) < 0.05


def test_compression_sparsity_and_stats():
    g = {"w": jnp.asarray(np.random.default_rng(2).standard_normal(1000))}
    err = init_error_state(g)
    sent, err2 = compress_grads(g, err, ratio=0.1)
    nz = int(jnp.sum(sent["w"] != 0))
    assert nz <= 110  # ~10 % (ties can add a few)
    # residual preserved: sent + err == g
    np.testing.assert_allclose(np.asarray(sent["w"] + err2["w"]),
                               np.asarray(g["w"]), rtol=1e-5, atol=1e-6)
    stats = compression_stats(g, 0.1)
    assert stats["compressed_bytes"] < stats["dense_bytes"]
