"""HLO cost parser: trip-count-aware FLOPs/bytes/collectives."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hlo_cost import parse_hlo_cost
from repro.core.roofline import model_flops_for_cell
from repro.configs.base import SHAPES
from repro.configs.registry import get_config


def _cost(fn, *args):
    return parse_hlo_cost(jax.jit(fn).lower(*args).compile().as_text())


def test_single_dot_exact():
    x = jnp.zeros((128, 64))
    w = jnp.zeros((64, 32))
    c = _cost(lambda x, w: x @ w, x, w)
    assert c.flops == 2 * 128 * 64 * 32


def test_scan_multiplies_by_trip_count():
    x = jnp.zeros((64, 64))
    ws = jnp.zeros((12, 64, 64))

    def f(x, ws):
        return jax.lax.scan(lambda c, w: (c @ w, None), x, ws)[0]

    c = _cost(f, x, ws)
    assert c.flops == 12 * 2 * 64**3
    assert 12 in c.trip_counts.values()


def test_grad_of_scan_counts_forward_and_backward():
    x = jnp.zeros((32, 32))
    ws = jnp.zeros((5, 32, 32))

    def loss(ws, x):
        return jax.lax.scan(lambda c, w: (c @ w, None), x, ws)[0].sum()

    c = _cost(jax.grad(loss), ws, x)
    # fwd 5 + bwd 2×5 dots
    assert c.flops == pytest.approx(15 * 2 * 32**3, rel=0.01)


def test_batch_dot_flops():
    a = jnp.zeros((4, 16, 24))
    b = jnp.zeros((4, 24, 8))
    c = _cost(lambda a, b: jnp.einsum("bik,bkj->bij", a, b), a, b)
    assert c.flops == 2 * 4 * 16 * 24 * 8


def test_bytes_reasonable_for_elementwise():
    x = jnp.zeros((1024, 1024), jnp.float32)
    c = _cost(lambda x: x * 2 + 1, x)
    # read + write ≈ 8 MB; allow fusion-dependent slack
    assert 4e6 < c.hbm_bytes < 3e7


def test_model_flops_for_cell_train_vs_decode():
    cfg = get_config("qwen3-4b")
    train = model_flops_for_cell(cfg, SHAPES["train_4k"])
    decode = model_flops_for_cell(cfg, SHAPES["decode_32k"])
    assert train / decode > 1e4  # 6·N·T vs 2·N·B
    n = cfg.param_count()
    assert train == pytest.approx(6 * n * SHAPES["train_4k"].tokens, rel=1e-6)


def test_moe_active_params_smaller():
    cfg = get_config("qwen3-moe-30b-a3b")
    assert cfg.param_count(active_only=True) < 0.2 * cfg.param_count()
    # ~30B total / ~3B active (plus embeddings)
    assert 25e9 < cfg.param_count() < 35e9
